"""Property-based tests over the solver core's invariants."""
import importlib.util
import random

import pytest

pytest.importorskip("hypothesis", reason="optional extra: pip install .[test]")
from hypothesis import HealthCheck, given, settings, strategies as st

HAS_Z3 = importlib.util.find_spec("z3") is not None
needs_z3 = pytest.mark.skipif(not HAS_Z3,
                              reason="optional extra: pip install .[z3]")

from repro.cgra import make_grid
from repro.core import (DFG, Edge, HeuristicConfig, MapperConfig, Node,
                        asap_alap, fold_kms, map_dfg, map_dfg_heuristic,
                        min_ii, rec_ii, res_ii, validate_mapping)
from repro.core.backends import encoding_to_cnf, solve_cdcl, solve_z3
from repro.core.sat_encoding import KMSEncoding
from repro.sat import CDCLSolver, CNF

def SETTINGS(max_examples=25):
    return dict(deadline=None, max_examples=max_examples,
                suppress_health_check=[HealthCheck.too_slow])


# ---------------------------------------------------------------------------
# random DFG generator
# ---------------------------------------------------------------------------


def random_dfg(seed: int, max_nodes: int = 12) -> DFG:
    rng = random.Random(seed)
    n = rng.randint(2, max_nodes)
    nodes = [Node(i) for i in range(1, n + 1)]
    edges = []
    seen = set()
    # forward edges respect id order -> forward subgraph is a DAG
    for dst in range(2, n + 1):
        for _ in range(rng.randint(0, 2)):
            src = rng.randint(1, dst - 1)
            if (src, dst) not in seen:
                seen.add((src, dst))
                edges.append(Edge(src, dst, 0))
    # a few back-edges with distance 1..2
    for _ in range(rng.randint(0, 2)):
        src = rng.randint(2, n)
        dst = rng.randint(1, src)
        if src != dst and (src, dst) not in seen:
            seen.add((src, dst))
            edges.append(Edge(src, dst, rng.randint(1, 2)))
    return DFG(nodes, edges, name=f"rand{seed}")


# ---------------------------------------------------------------------------
# schedule / KMS invariants
# ---------------------------------------------------------------------------


@given(st.integers(0, 10_000))
@settings(**SETTINGS())
def test_kms_partition_property(seed):
    """Every node's KMS slots = its mobility window, bijectively."""
    dfg = random_dfg(seed)
    ms = asap_alap(dfg)
    for ii in range(1, ms.length + 2):
        kms = fold_kms(ms, ii)
        for n in dfg.node_ids():
            window = list(ms.mobility(n))
            slots = kms.slots[n]
            assert len(slots) == len(window)
            # schedule_time reverses the fold: q - pad == MS row
            recovered = sorted(kms.schedule_time(s) - kms.pad for s in slots)
            assert recovered == window
            for s in slots:
                assert 0 <= s.c < ii
                assert 0 <= s.it < kms.num_folds


@given(st.integers(0, 10_000))
@settings(**SETTINGS())
def test_asap_alap_sound(seed):
    dfg = random_dfg(seed)
    ms = asap_alap(dfg)
    for n in dfg.node_ids():
        assert 0 <= ms.asap[n] <= ms.alap[n] < ms.length
    for e in dfg.forward_edges():
        assert ms.asap[e.src] < ms.asap[e.dst]
        assert ms.alap[e.src] < ms.alap[e.dst]


@needs_z3
@given(st.integers(0, 10_000))
@settings(**SETTINGS())
def test_mii_lower_bound_is_sound(seed):
    """No mapping can exist below mII: the SAT instance must be UNSAT there.

    (Checks the encoder agrees with the analytic bound — the paper's
    Eq. 2 soundness.)"""
    dfg = random_dfg(seed, max_nodes=8)
    grid = make_grid(2, 2)
    mii = min_ii(dfg, grid.num_pes)
    assert mii >= res_ii(dfg, 4)
    assert mii >= rec_ii(dfg)
    if mii > 1:
        ms = asap_alap(dfg)
        kms = fold_kms(ms, mii - 1)
        enc = KMSEncoding(dfg, kms, grid)
        status, _, _ = solve_z3(enc, timeout_s=20)
        assert status == "unsat"


# ---------------------------------------------------------------------------
# mapper end-to-end invariants
# ---------------------------------------------------------------------------


@given(st.integers(0, 10_000))
@settings(**SETTINGS(15))
def test_mapper_output_always_validates(seed):
    dfg = random_dfg(seed, max_nodes=10)
    grid = make_grid(2, 2)
    res = map_dfg(dfg, grid, MapperConfig(per_ii_timeout_s=20, ii_max=12,
                                          validate=False))
    if res.mapping is not None:
        assert validate_mapping(res.mapping) == []
        assert res.mapping.ii >= res.mii


@given(st.integers(0, 10_000))
@settings(**SETTINGS(10))
def test_sat_never_worse_than_heuristic(seed):
    """Exactness: on instances both solve, SAT-MapIt's II <= heuristic II."""
    dfg = random_dfg(seed, max_nodes=9)
    grid = make_grid(2, 2)
    sat_res = map_dfg(dfg, grid, MapperConfig(per_ii_timeout_s=20, ii_max=12))
    heur = map_dfg_heuristic(dfg, grid, HeuristicConfig(
        seed=seed, tries_per_ii=6, ii_max=12))
    if sat_res.mapping and heur.mapping:
        assert sat_res.mapping.ii <= heur.mapping.ii
    if heur.mapping:
        # heuristic results must be legal under the same validator
        assert validate_mapping(heur.mapping) == []


@needs_z3
@given(st.integers(0, 10_000))
@settings(**SETTINGS(8))
def test_backends_agree(seed):
    """Z3 and our CDCL agree on satisfiability of the same encoding."""
    dfg = random_dfg(seed, max_nodes=7)
    grid = make_grid(2, 2)
    ms = asap_alap(dfg)
    mii = min_ii(dfg, grid.num_pes)
    for ii in (mii, mii + 1):
        kms = fold_kms(ms, ii)
        enc = KMSEncoding(dfg, kms, grid)
        s1, _, _ = solve_z3(enc, timeout_s=20)
        s2, _, _ = solve_cdcl(enc, timeout_s=20)
        assert s1 == s2


@given(st.integers(0, 10_000))
@settings(**SETTINGS(8))
def test_symmetry_breaking_preserves_satisfiability(seed):
    """PE pinning on the torus must not change SAT/UNSAT answers."""
    dfg = random_dfg(seed, max_nodes=7)
    grid = make_grid(3, 3)
    ms = asap_alap(dfg)
    ii = min_ii(dfg, grid.num_pes)
    kms = fold_kms(ms, ii)
    plain = KMSEncoding(dfg, kms, grid, symmetry_break=False)
    broken = KMSEncoding(dfg, kms, grid, symmetry_break=True)
    solve = solve_z3 if HAS_Z3 else solve_cdcl
    s1, _, _ = solve(plain, timeout_s=20)
    s2, _, _ = solve(broken, timeout_s=20)
    assert s1 == s2


# ---------------------------------------------------------------------------
# SAT substrate
# ---------------------------------------------------------------------------


@given(st.integers(0, 100_000))
@settings(**SETTINGS())
def test_cdcl_vs_bruteforce_random3sat(seed):
    rng = random.Random(seed)
    n = rng.randint(3, 10)
    m = rng.randint(n, 6 * n)
    cnf = CNF()
    cnf.ensure_var(n)
    for _ in range(m):
        vs = rng.sample(range(1, n + 1), 3)
        cnf.add_clause(tuple(v if rng.random() < .5 else -v for v in vs))
    solver = CDCLSolver(cnf)
    res = solver.solve(timeout_s=10)
    exp = any(
        all(any((a >> (abs(l) - 1)) & 1 == (l > 0) for l in c)
            for c in cnf.clauses)
        for a in range(1 << n))
    assert (res == "sat") == exp
    if res == "sat":
        model = solver.model()
        assert all(any(model[abs(l)] == (l > 0) for l in c)
                   for c in cnf.clauses)


@given(st.integers(0, 10_000), st.integers(2, 9))
@settings(**SETTINGS())
def test_amo_encodings_equivalent(seed, k):
    """Pairwise and sequential at-most-one admit exactly the same models
    (projected to the original variables)."""
    rng = random.Random(seed)
    lits = list(range(1, k + 1))

    def count_models(encoding):
        cnf = CNF()
        cnf.ensure_var(k)
        if encoding == "pairwise":
            cnf.at_most_one_pairwise(lits)
        else:
            cnf.at_most_one_sequential(lits)
        count = 0
        for a in range(1 << k):
            assign = {v: bool((a >> (v - 1)) & 1) for v in range(1, k + 1)}
            # extend to aux vars by brute force over the remainder
            aux = list(range(k + 1, cnf.num_vars + 1))
            ok = False
            for b in range(1 << len(aux)):
                full = dict(assign)
                for i, v in enumerate(aux):
                    full[v] = bool((b >> i) & 1)
                if all(any(full[abs(l)] == (l > 0) for l in c)
                       for c in cnf.clauses):
                    ok = True
                    break
            count += ok
        return count

    if k <= 6:  # brute-force cost guard
        assert count_models("pairwise") == count_models("sequential")
