"""Observability subsystem: trace spans, merged multi-process traces,
attribution, Chrome export, metrics registry, solver deep telemetry and
the cumulative solver-stats fix.

Everything runs on the dependency-free CDCL backend over 2x2 grids so
the module stays inside tier-1 time budgets. The module-scoped fixture
guarantees tracing is switched off again even when a test fails, so the
global trace state never leaks into other test modules.
"""
import glob
import json
import os
import time

import pytest

from repro.core import MapperConfig
from repro.obs import MetricsRegistry, trace
from repro.obs.cli import main as trace_cli
from repro.obs.metrics import Histogram
from repro.obs.report import (
    attribution,
    load,
    render_report,
    to_chrome,
    validate,
)
from repro.sat import CDCLSolver, CNF
from repro.sat.cdcl import Stats
from repro.toolchain import Toolchain

CDCL = MapperConfig(backend="cdcl", per_ii_timeout_s=10.0,
                    total_timeout_s=30.0)


@pytest.fixture(autouse=True)
def _trace_state_isolated():
    """Every test starts and ends with tracing off."""
    trace.disable()
    yield
    trace.disable()


def _shards(d):
    return sorted(glob.glob(os.path.join(str(d), "shard-*.jsonl")))


# ---------------------------------------------------------------------------
# span core: schema round-trip, disabled path, error capture
# ---------------------------------------------------------------------------


def test_schema_round_trip(tmp_path):
    trace.enable(str(tmp_path))
    with trace.span("outer", kernel="k", n=3) as outer:
        outer.event("hello", flag=True, x=1.5)
        with trace.span("inner") as inner:
            inner.set(status="ok")
    trace.disable()

    recs = load(str(tmp_path))
    assert validate(recs) == []
    spans = {r["name"]: r for r in recs if r["k"] == "span"}
    events = [r for r in recs if r["k"] == "event"]
    assert set(spans) == {"outer", "inner"}
    out, inn = spans["outer"], spans["inner"]
    # tree structure and id propagation
    assert out["parent"] is None
    assert inn["parent"] == out["span"]
    assert inn["trace"] == out["trace"]
    # typed attributes survive the JSONL round-trip
    assert out["attrs"] == {"kernel": "k", "n": 3}
    assert inn["attrs"] == {"status": "ok"}
    assert events == [e for e in events if e["span"] == out["span"]]
    assert events[0]["attrs"] == {"flag": True, "x": 1.5}
    for r in recs:
        assert r["v"] == trace.SCHEMA_VERSION
        assert r["pid"] == os.getpid()


def test_disabled_path_writes_nothing(tmp_path):
    # enable then disable: later spans must not touch the old directory
    trace.enable(str(tmp_path))
    trace.disable()
    assert not trace.enabled() and trace.trace_dir() is None
    s1 = trace.span("a", x=1)
    s2 = trace.span("b")
    # the no-op path is one shared singleton — zero allocation, zero I/O
    assert s1 is s2 is trace.NULL_SPAN
    with s1 as sp:
        sp.set(y=2).event("never")
        trace.event("never-either")
    assert trace.shipping_context() is None
    assert trace.current() is None
    assert _shards(tmp_path) == []


def test_timed_span_measures_duration_when_disabled():
    with trace.timed_span("stage.x") as t:
        time.sleep(0.01)
    assert t.dur >= 0.005
    # and it never became the current span nor wrote anything
    assert trace.current() is None


def test_span_records_error_attribute(tmp_path):
    trace.enable(str(tmp_path))
    with pytest.raises(ValueError):
        with trace.span("boom"):
            raise ValueError("nope")
    trace.disable()
    recs = load(str(tmp_path))
    assert validate(recs) == []
    (rec,) = [r for r in recs if r["k"] == "span"]
    assert rec["attrs"]["error"] == "ValueError"


def test_shipped_parent_pins_ids_and_reenables(tmp_path):
    trace.enable(str(tmp_path))
    with trace.span("parent") as parent:
        ctx = parent.ship()
    trace.disable()
    # a "worker" with tracing off receives the shipped context
    with trace.span("child", parent=ctx) as child:
        assert child.trace_id == ctx["trace"]
        assert child.parent_id == ctx["span"]
    trace.disable()
    recs = load(str(tmp_path))
    assert validate(recs) == []
    spans = {r["name"]: r for r in recs if r["k"] == "span"}
    assert spans["child"]["parent"] == spans["parent"]["span"]


def test_validate_flags_malformed_traces():
    dangling = [{"v": 1, "k": "span", "trace": "t", "span": "a",
                 "parent": "missing", "name": "x", "pid": 1, "tid": 1,
                 "ts": 0.0, "dur": 0.1, "attrs": {}}]
    assert any("parent" in p for p in validate(dangling))
    assert any("unknown schema" in p
               for p in validate([{"v": 99, "k": "span"}]))
    assert any("unknown kind" in p
               for p in validate([{"v": 1, "k": "wat"}]))


# ---------------------------------------------------------------------------
# toolchain integration: timings projection, attribution, fleet merge
# ---------------------------------------------------------------------------


def test_timings_projection_survives_tracing_off():
    cr = Toolchain("2x2", CDCL).compile("bitcount")
    assert cr.status == "ok"
    assert set(cr.timings) == {"source", "map", "assemble", "metrics"}
    assert all(v >= 0.0 for v in cr.timings.values())
    assert cr.timings["map"] > 0.0


def test_traced_compile_attributes_95_percent(tmp_path):
    trace.enable(str(tmp_path))
    cr = Toolchain("2x2", CDCL).compile("gsm")  # CEGAR-active point
    trace.disable()
    assert cr.status == "ok"
    recs = load(str(tmp_path))
    assert validate(recs) == []
    att = attribution(recs)
    names = {r["name"] for r in recs if r["k"] == "span"}
    assert {"compile", "stage.map", "mapper.ladder", "mapper.attempt_ii",
            "mapper.encode", "solver.solve", "mapper.oracle"} <= names
    # the acceptance bar: >= 95% of compile wall time in named spans
    assert att["attributed"] >= 0.95
    # traced timings must still project into CompileResult
    assert set(cr.timings) == {"source", "map", "assemble", "metrics"}
    # report renders and gates
    text = render_report(recs, min_attribution=0.95)
    assert "PASS" in text and "compile" in text


def test_traced_portfolio_compile_attributes_95_percent(tmp_path):
    trace.enable(str(tmp_path))
    cfg = MapperConfig(strategy="portfolio:cdcl-seq+cdcl-pair",
                       per_ii_timeout_s=15.0, total_timeout_s=60.0)
    cr = Toolchain("2x2", cfg).compile("gsm")
    trace.disable()
    assert cr.status == "ok"
    recs = load(str(tmp_path))
    assert validate(recs) == []
    att = attribution(recs)
    names = {r["name"] for r in recs if r["k"] == "span"}
    assert "portfolio.race" in names and "mapper.attempt_ii" in names
    assert att["attributed"] >= 0.95


def test_solver_progress_events_reach_the_span(tmp_path, monkeypatch):
    orig = CDCLSolver.__init__

    def eager(self, *a, **k):
        orig(self, *a, **k)
        self.progress_every = 1  # sample on every conflict

    monkeypatch.setattr(CDCLSolver, "__init__", eager)
    trace.enable(str(tmp_path))
    cr = Toolchain("2x2", CDCL).compile("gsm")
    trace.disable()
    assert cr.status == "ok"
    recs = load(str(tmp_path))
    samples = [r for r in recs if r.get("k") == "event"
               and r["name"] == "solver.progress"]
    assert samples, "expected periodic solver.progress events"
    counts = [s["attrs"]["conflicts"] for s in samples]
    assert counts == sorted(counts) and counts[0] >= 1
    for s in samples:
        assert {"conflicts", "decisions", "propagations", "restarts",
                "learned"} <= set(s["attrs"])
    # every sample's owner is a recorded solver.solve span
    solve_ids = {r["span"] for r in recs
                 if r.get("k") == "span" and r["name"] == "solver.solve"}
    assert all(s["span"] in solve_ids for s in samples)


def test_fleet_merge_spans_processes(tmp_path):
    trace.enable(str(tmp_path))
    tc = Toolchain("4x4", MapperConfig(backend="cdcl", per_ii_timeout_s=15,
                                       total_timeout_s=60, ii_max=32))
    crs = tc.compile_many(["dotprod", "bitcount"], jobs=2)
    trace.disable()
    assert [c.status for c in crs] == ["ok", "ok"]
    recs = load(str(tmp_path))
    assert validate(recs) == []  # every cross-process parent resolves
    assert len(_shards(tmp_path)) >= 2  # workers wrote their own shards
    pids = {r["pid"] for r in recs}
    assert len(pids) >= 2
    spans = [r for r in recs if r["k"] == "span"]
    by_id = {r["span"]: r for r in spans}
    points = [r for r in spans if r["name"] == "fleet.point"]
    workers = [r for r in spans if r["name"] == "worker.map"]
    assert len(points) == 2 and len(workers) == 2
    for w in workers:
        assert by_id[w["parent"]]["name"] == "fleet.point"
        assert w["pid"] != by_id[w["parent"]]["pid"]
    # one trace, rooted at the batch-level fleet span, covers the fan-out
    assert len({r["trace"] for r in spans}) == 1
    roots = [r for r in spans if r["parent"] is None]
    assert [r["name"] for r in roots] == ["fleet"]
    for p in points:
        assert by_id[p["parent"]]["name"] == "fleet"


# ---------------------------------------------------------------------------
# analysis layer: attribution math, Chrome export, CLI
# ---------------------------------------------------------------------------


def _mk_span(sid, parent, name, ts, dur, trace_id="t"):
    return {"v": 1, "k": "span", "trace": trace_id, "span": sid,
            "parent": parent, "name": name, "pid": 1, "tid": 1,
            "ts": ts, "dur": dur, "attrs": {}}


def test_attribution_math_on_synthetic_tree():
    recs = [
        _mk_span("r", None, "root", 0.0, 10.0),
        _mk_span("a", "r", "child", 0.0, 4.0),
        _mk_span("b", "r", "child", 3.0, 5.0),  # overlaps a: union = 8
    ]
    att = attribution(recs)
    (root,) = att["roots"]
    assert root["attributed"] == pytest.approx(0.8)
    assert att["attributed"] == pytest.approx(0.8)
    assert att["by_name"]["root"]["self_s"] == pytest.approx(2.0)
    assert att["by_name"]["child"]["total_s"] == pytest.approx(9.0)


def test_chrome_export_structure(tmp_path):
    trace.enable(str(tmp_path))
    with trace.span("outer") as sp:
        sp.event("tick")
        with trace.span("inner"):
            pass
    trace.disable()
    recs = load(str(tmp_path))
    doc = to_chrome(recs)
    phases = sorted(e["ph"] for e in doc["traceEvents"])
    assert phases == ["X", "X", "i"]
    assert all(e["ts"] >= 0.0 for e in doc["traceEvents"])
    assert doc["displayTimeUnit"] == "ms"
    # spans carry their ids so the viewer can cross-reference
    xs = [e for e in doc["traceEvents"] if e["ph"] == "X"]
    assert all("span" in e["args"] and "trace" in e["args"] for e in xs)


def test_trace_cli_report_check_export(tmp_path, capsys):
    trace.enable(str(tmp_path / "tr"))
    with trace.span("compile", kernel="k"):
        with trace.span("stage.map"):
            time.sleep(0.002)
    trace.disable()
    assert trace_cli(["report", str(tmp_path / "tr")]) == 0
    assert "aggregate attribution" in capsys.readouterr().out
    assert trace_cli(["check", str(tmp_path / "tr"),
                      "--min-attribution", "0.0"]) == 0
    out = str(tmp_path / "chrome.json")
    assert trace_cli(["export", str(tmp_path / "tr"),
                      "--chrome", "-o", out]) == 0
    doc = json.load(open(out))
    assert len(doc["traceEvents"]) == 2
    # an empty/nonexistent trace is an error, not a crash
    assert trace_cli(["report", str(tmp_path / "nope")]) == 1


# ---------------------------------------------------------------------------
# metrics registry
# ---------------------------------------------------------------------------


def test_metrics_counters_and_histograms():
    m = MetricsRegistry()
    m.inc("hits")
    m.inc("hits", 4)
    for v in range(1, 101):
        m.observe("lat_s", float(v))
    snap = m.snapshot()
    assert snap["counters"]["hits"] == 5
    h = snap["histograms"]["lat_s"]
    assert h["count"] == 100 and h["min"] == 1.0 and h["max"] == 100.0
    assert h["p50"] == 50.0 and h["p90"] == 90.0 and h["p99"] == 99.0
    assert h["sum"] == pytest.approx(5050.0)


def test_histogram_reservoir_keeps_exact_aggregates():
    h = Histogram("lat_s", window=8)
    for v in range(1, 1001):
        h.observe(float(v))
    snap = h.snapshot()
    # count/sum/min/max are exact even though the reservoir is tiny
    assert snap["count"] == 1000 and snap["max"] == 1000.0
    assert snap["min"] == 1.0 and snap["sum"] == pytest.approx(500500.0)
    # percentiles come from the sliding window of recent samples
    assert 992.0 <= snap["p50"] <= 1000.0


def test_empty_histogram_snapshot():
    h = Histogram("empty")
    snap = h.snapshot()
    assert snap == {"count": 0}
    assert h.percentile(0.5) is None


# ---------------------------------------------------------------------------
# satellite: cumulative CDCL solver stats
# ---------------------------------------------------------------------------


def _pigeonhole(holes):
    cnf = CNF()
    n = holes + 1
    var = {(p, h): cnf.new_var() for p in range(n) for h in range(holes)}
    for p in range(n):
        cnf.add_clause([var[(p, h)] for h in range(holes)])
    for h in range(holes):
        for p1 in range(n):
            for p2 in range(p1 + 1, n):
                cnf.add_clause((-var[(p1, h)], -var[(p2, h)]))
    return cnf


def test_solver_time_s_accumulates_across_solves():
    cnf = _pigeonhole(4)
    del cnf.clauses[0]  # SAT variant so solve() can be repeated
    s = CDCLSolver(cnf)
    assert s.solve(timeout_s=30) == "sat"
    t1, last1 = s.stats.time_s, s.stats.last_solve_s
    assert t1 > 0.0 and t1 == pytest.approx(last1)
    assert s.solve(timeout_s=30) == "sat"
    # cumulative total strictly grows; last_solve_s is per-call
    assert s.stats.time_s > t1
    assert s.stats.last_solve_s < s.stats.time_s
    assert s.stats.time_s == pytest.approx(last1 + s.stats.last_solve_s)


def test_stats_defaults_include_last_solve():
    st = Stats()
    assert st.time_s == 0.0 and st.last_solve_s == 0.0


def test_progress_callback_fires_per_conflict():
    s = CDCLSolver(_pigeonhole(4))
    s.progress_every = 1
    seen = []
    s.on_progress = lambda st: seen.append(st.conflicts)
    assert s.solve(timeout_s=30) == "unsat"
    assert s.stats.conflicts > 0
    assert len(seen) == s.stats.conflicts
    assert seen == sorted(seen)
