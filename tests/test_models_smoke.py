"""Per-architecture smoke tests: reduced configs, one forward + one train
step + one decode step on CPU; shape and finiteness checks."""
import numpy as np
import pytest

pytest.importorskip("jax", reason="optional extra: pip install .[jax]")
import jax
import jax.numpy as jnp

from repro.configs import ARCH_IDS, get_smoke
from repro.configs.base import RunConfig
from repro.models import Model, count_params, init_decode_state

RUN = RunConfig(remat="none", attn_chunk=64)


def make_batch(cfg, key, batch=2, seq=16):
    tks = jax.random.randint(key, (batch, seq), 0, cfg.vocab_size)
    batch_d = {
        "tokens": tks,
        "labels": jnp.roll(tks, -1, axis=1),
        "loss_mask": jnp.ones((batch, seq), jnp.float32),
    }
    if cfg.family == "vlm":
        batch_d["patch_embeds"] = jax.random.normal(
            key, (batch, cfg.num_patches, cfg.d_model), jnp.float32)
    if cfg.enc_layers:
        batch_d["frame_embeds"] = jax.random.normal(
            key, (batch, cfg.enc_seq, cfg.d_model), jnp.float32)
    return batch_d


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_smoke_forward_shapes_and_finite(arch):
    cfg = get_smoke(arch)
    model = Model(cfg, RUN)
    key = jax.random.PRNGKey(0)
    params = model.init(key)
    batch = make_batch(cfg, key)
    logits = jax.jit(model.forward)(params, batch)
    assert logits.shape == (2, 16, cfg.vocab_size)
    assert bool(jnp.isfinite(logits).all()), "non-finite logits"


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_smoke_loss_and_grad_step(arch):
    cfg = get_smoke(arch)
    model = Model(cfg, RUN)
    key = jax.random.PRNGKey(1)
    params = model.init(key)
    batch = make_batch(cfg, key)

    @jax.jit
    def step(p):
        loss, grads = jax.value_and_grad(model.loss)(p, batch)
        new_p = jax.tree_util.tree_map(lambda w, g: w - 1e-3 * g, p, grads)
        return loss, new_p

    loss, new_params = step(params)
    assert bool(jnp.isfinite(loss)), "non-finite loss"
    assert loss > 0
    gnorm = jnp.sqrt(sum(jnp.sum(jnp.square(g))
                         for g in jax.tree_util.tree_leaves(
                             jax.tree_util.tree_map(
                                 lambda a, b: a - b, params, new_params))))
    assert bool(jnp.isfinite(gnorm)) and float(gnorm) > 0, "no gradient signal"


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_smoke_decode_step(arch):
    cfg = get_smoke(arch)
    model = Model(cfg, RUN)
    key = jax.random.PRNGKey(2)
    params = model.init(key)
    B, max_len = 2, 32
    state = init_decode_state(cfg, B, max_len)
    if cfg.enc_layers:
        # encoder context for cross-attention (stub frames)
        enc = model._encode(params, jax.random.normal(
            key, (B, cfg.enc_seq, cfg.d_model), jnp.float32))
        cross = model._cross_kv_from_enc(params, enc)
        state = state._replace(cross_kv=cross)
    tokens = jax.random.randint(key, (B, 1), 0, cfg.vocab_size)
    step = jax.jit(model.decode_step)
    logits, state = step(params, state, tokens)
    assert logits.shape == (B, 1, cfg.vocab_size)
    assert bool(jnp.isfinite(logits).all())
    assert int(state.pos) == 1
    logits2, state = step(params, state, tokens)
    assert int(state.pos) == 2
    assert bool(jnp.isfinite(logits2).all())


def test_param_counts_match_analytic():
    """ModelConfig.param_count() agrees with the real parameter tree."""
    for arch in ["minicpm-2b", "granite-moe-3b-a800m", "mamba2-1.3b"]:
        cfg = get_smoke(arch)
        model = Model(cfg, RunConfig())
        tree_count = count_params(model.defs)
        analytic = cfg.param_count()
        # patch_proj / enc extras are excluded from the analytic count
        assert abs(tree_count - analytic) / max(analytic, 1) < 0.05, arch


def test_full_config_param_counts():
    """Sanity: full configs land near their nameplate sizes."""
    from repro.configs import get_config
    expect = {
        "llama3-405b": (380e9, 430e9),
        "kimi-k2-1t-a32b": (0.9e12, 1.2e12),
        "mamba2-1.3b": (1.0e9, 1.6e9),
        "llama3.2-3b": (2.5e9, 3.9e9),
    }
    for arch, (lo, hi) in expect.items():
        n = get_config(arch).param_count()
        assert lo <= n <= hi, f"{arch}: {n/1e9:.1f}B outside [{lo/1e9}, {hi/1e9}]"
