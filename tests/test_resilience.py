"""Resilient compile fleet: chaos determinism, worker supervision
(crash healing, parent-side deadline kills), the retry/degradation
ladder, failure attribution, cache-poisoning protection, and
crash-resumable sweeps.

Faults are injected with the deterministic chaos harness
(``repro.toolchain.chaos``), keyed off ``REPRO_CHAOS`` so forked workers
and subprocess sweeps inherit the campaign with zero plumbing.  All
solving runs on the dependency-free CDCL backend over 2x2/2x3 grids so
the whole module stays inside tier-1 time budgets.
"""
import json
import os
import subprocess
import sys
import time

import pytest

from repro.core import MapperConfig
from repro.dse.journal import SweepJournal
from repro.dse.sweep import SweepConfig, run_sweep
from repro.toolchain import ResilienceConfig, Toolchain
from repro.toolchain.chaos import ENV_KEY, ChaosSpec
from repro.toolchain.resilience import (FailureKind, _classify_exitcode,
                                        failure_record, failure_text)

CDCL = MapperConfig(backend="cdcl", per_ii_timeout_s=10.0,
                    total_timeout_s=30.0)

SRC_DIR = os.path.join(
    os.path.dirname(os.path.dirname(os.path.abspath(__file__))), "src")


def _has_z3():
    try:
        import z3  # noqa: F401
        return True
    except ImportError:
        return False


def _arm(monkeypatch, **kw):
    spec = ChaosSpec(**kw)
    monkeypatch.setenv(ENV_KEY, spec.to_json())
    return spec


# ---------------------------------------------------------------------------
# chaos harness determinism
# ---------------------------------------------------------------------------


def test_chaos_spec_env_round_trip():
    spec = ChaosSpec(seed=7, rate=0.5, kinds=("crash", "hang"),
                     attempts=(0, 1), hang_s=12.5, abort_after_points=3)
    assert ChaosSpec.from_json(spec.to_json()) == spec


def test_chaos_spec_rejects_unknown_fields_and_kinds():
    with pytest.raises(ValueError, match="unknown ChaosSpec fields"):
        ChaosSpec.from_json('{"rte": 0.5}')
    with pytest.raises(ValueError, match="unknown chaos kinds"):
        ChaosSpec.from_json('{"kinds": ["segfault"]}')


def test_chaos_decide_is_deterministic_and_rate_bounded():
    spec = ChaosSpec(seed=1, rate=0.3)
    kernels = [f"k{i}" for i in range(200)]
    first = [spec.decide(k, "2x2", 0) for k in kernels]
    assert first == [spec.decide(k, "2x2", 0) for k in kernels]
    hit_rate = sum(1 for d in first if d) / len(first)
    assert 0.15 < hit_rate < 0.45  # ~rate, hash-derived
    # ineligible attempts and other seeds decide independently
    assert all(spec.decide(k, "2x2", 5) is None for k in kernels)
    other = ChaosSpec(seed=2, rate=0.3)
    assert [other.decide(k, "2x2", 0) for k in kernels] != first


def test_backoff_is_deterministic_and_capped():
    rcfg = ResilienceConfig(backoff_base_s=0.1, backoff_cap_s=0.4,
                            jitter=0.5)
    series = [rcfg.backoff_s("point", r) for r in range(6)]
    assert series == [rcfg.backoff_s("point", r) for r in range(6)]
    assert all(b <= 0.4 * 1.5 for b in series)
    assert rcfg.backoff_s("other", 0) != series[0]


def test_failure_record_and_text():
    try:
        raise ValueError("boom")
    except ValueError as e:
        rec = failure_record(FailureKind.SOLVER_ERROR, "map", e, attempt=2)
    assert rec["kind"] == "solver-error" and rec["stage"] == "map"
    assert rec["type"] == "ValueError" and rec["message"] == "boom"
    assert rec["attempt"] == 2 and "ValueError: boom" in rec["traceback"]
    assert failure_text(rec) == "ValueError: boom"
    assert failure_text(None) is None


def test_exitcode_classification():
    import signal

    assert _classify_exitcode(-signal.SIGKILL) == FailureKind.OOM
    assert _classify_exitcode(-signal.SIGSEGV) == FailureKind.WORKER_CRASH
    assert _classify_exitcode(1) == FailureKind.WORKER_CRASH
    assert _classify_exitcode(None) == FailureKind.WORKER_CRASH


# ---------------------------------------------------------------------------
# supervision: crash healing and deadline kills (real worker processes)
# ---------------------------------------------------------------------------


def test_worker_crash_is_healed_and_retried(monkeypatch):
    _arm(monkeypatch, rate=1.0, kinds=("crash",), attempts=(0,))
    tc = Toolchain((2, 2), CDCL)
    res = tc.compile_many(["bitcount", "reversebits"], grids=[(2, 2)],
                          jobs=2)
    for cr in res:
        assert cr.status == "ok"
        assert cr.retries == 1
        assert cr.failure_kind == FailureKind.WORKER_CRASH
        assert "exited with code" in cr.failure["message"]


def test_hung_worker_is_killed_within_deadline(monkeypatch):
    """The parent-side deadline must SIGKILL a wedged worker within 2x
    the per-point budget and recycle the slot; the injected hang would
    otherwise sleep for 60s."""
    budget = 2.0
    _arm(monkeypatch, rate=1.0, kinds=("hang",), attempts=(0,), hang_s=60.0)
    rcfg = ResilienceConfig(deadline_factor=1.0, deadline_slack_s=0.5,
                            max_retries=1)
    cfg = MapperConfig(backend="cdcl", per_ii_timeout_s=1.0,
                       total_timeout_s=budget)
    tc = Toolchain((2, 2), cfg)
    t0 = time.monotonic()
    res = tc.compile_many(["bitcount", "reversebits"], grids=[(2, 2)],
                          jobs=2, resilience=rcfg)
    elapsed = time.monotonic() - t0
    for cr in res:  # both slots hung in parallel; both killed + retried
        assert cr.status == "ok"
        assert cr.retries == 1
        assert cr.failure_kind == FailureKind.DEADLINE
        assert "deadline" in cr.failure["message"]
    # deadline = 1.0*budget + 0.5s slack; generous pad for CI schedulers,
    # but nowhere near the 60s hang
    assert elapsed < 2 * budget + 3.0


def test_fleet_matches_inline_results(monkeypatch):
    """Chaos-free fleet and inline runs produce identical verdicts."""
    monkeypatch.delenv(ENV_KEY, raising=False)
    kernels = ["bitcount", "reversebits"]
    tc = Toolchain((2, 2), CDCL)
    inline = tc.compile_many(kernels, grids=[(2, 2), (2, 3)], jobs=1)
    fleet = tc.compile_many(kernels, grids=[(2, 2), (2, 3)], jobs=2)
    assert [(c.kernel, c.size, c.status, c.ii) for c in inline] == \
        [(c.kernel, c.size, c.status, c.ii) for c in fleet]
    assert all(c.retries == 0 and c.failure is None for c in fleet)


# ---------------------------------------------------------------------------
# the retry/degradation ladder
# ---------------------------------------------------------------------------


def test_persistent_fault_degrades_down_the_ladder(monkeypatch):
    """Solver errors on attempts 0 and 1 exhaust max_retries=1; the
    backend-flip rung is skipped (no z3 installed), so the point lands
    on oracle-off and succeeds there."""
    if "z3" in sys.modules or _has_z3():
        pytest.skip("z3 installed: the ladder would flip backends first")
    _arm(monkeypatch, rate=1.0, kinds=("solver-error",), attempts=(0, 1))
    rcfg = ResilienceConfig(max_retries=1, backoff_base_s=0.01,
                            backoff_cap_s=0.05)
    tc = Toolchain((2, 2), CDCL)
    for jobs in (1, 2):
        (cr,) = tc.compile_many(["bitcount"], grids=[(2, 2)], jobs=jobs,
                                resilience=rcfg)
        assert cr.status == "ok"
        assert cr.degraded == "oracle-off"
        assert cr.retries == 2
        assert cr.failure_kind == FailureKind.SOLVER_ERROR


def test_exhausted_ladder_yields_typed_failed_row(monkeypatch):
    """A fault that survives every rung terminates as a typed
    ``status="failed"`` result — never an exception out of
    ``compile_many``, never a lost point."""
    _arm(monkeypatch, rate=1.0, kinds=("solver-error",),
         attempts=tuple(range(12)))
    rcfg = ResilienceConfig(max_retries=1, backoff_base_s=0.01,
                            backoff_cap_s=0.05)
    tc = Toolchain((2, 2), CDCL)
    for jobs in (1, 2):
        (cr,) = tc.compile_many(["bitcount"], grids=[(2, 2)], jobs=jobs,
                                resilience=rcfg)
        assert cr.status == "failed"
        assert cr.stage == "map"
        assert cr.failure_kind == FailureKind.SOLVER_ERROR
        assert cr.failure["type"] == "ChaosError"
        assert "traceback" in cr.failure
        assert cr.error and "ChaosError" in cr.error


def test_degraded_results_are_not_cached(tmp_path, monkeypatch):
    _arm(monkeypatch, rate=1.0, kinds=("solver-error",), attempts=(0, 1))
    rcfg = ResilienceConfig(max_retries=1, backoff_base_s=0.01,
                            backoff_cap_s=0.05)
    tc = Toolchain((2, 2), CDCL, cache=str(tmp_path / "cache"))
    (cr,) = tc.compile_many(["bitcount"], grids=[(2, 2)], jobs=1,
                            resilience=rcfg)
    assert cr.status == "ok" and cr.degraded == "oracle-off"
    assert len(tc.cache) == 0  # a rung result must not poison the key


# ---------------------------------------------------------------------------
# cache-poisoning protection (satellite: only terminal verdicts cached)
# ---------------------------------------------------------------------------


def test_transient_failure_is_not_cached_and_retried_next_sweep(
        tmp_path, monkeypatch):
    """A point that fails this sweep (injected transient solver error,
    ladder disabled) must be re-attempted — and succeed — on the next
    sweep instead of replaying a poisoned cache entry."""
    _arm(monkeypatch, rate=1.0, kinds=("solver-error",),
         attempts=tuple(range(12)))
    rcfg = ResilienceConfig(max_retries=0, degradation=())
    tc = Toolchain((2, 2), CDCL, cache=str(tmp_path / "cache"))
    (cr,) = tc.compile_many(["bitcount"], grids=[(2, 2)], jobs=1,
                            resilience=rcfg)
    assert cr.status == "failed"
    assert len(tc.cache) == 0  # the failure never reached the cache

    monkeypatch.delenv(ENV_KEY)
    (cr2,) = tc.compile_many(["bitcount"], grids=[(2, 2)], jobs=1,
                             resilience=rcfg)
    assert cr2.status == "ok" and not cr2.cache_hit  # genuinely re-solved
    assert len(tc.cache) == 1
    (cr3,) = tc.compile_many(["bitcount"], grids=[(2, 2)], jobs=1)
    assert cr3.status == "ok" and cr3.cache_hit


def test_corrupted_cache_entry_is_quarantined_and_attributed(
        tmp_path, monkeypatch):
    """The chaos cache-corrupt fault tears the entry right after the
    parent writes it; the next sweep must quarantine it, re-solve, and
    attribute the loss as ``cache-corrupt`` — not silently re-miss."""
    _arm(monkeypatch, rate=1.0, kinds=("cache-corrupt",), attempts=(0,))
    tc = Toolchain((2, 2), CDCL, cache=str(tmp_path / "cache"))
    (cr,) = tc.compile_many(["bitcount"], grids=[(2, 2)], jobs=1)
    assert cr.status == "ok"  # the solve itself is unaffected

    (cr2,) = tc.compile_many(["bitcount"], grids=[(2, 2)], jobs=1)
    assert cr2.status == "ok" and not cr2.cache_hit
    assert cr2.failure_kind == FailureKind.CACHE_CORRUPT
    assert tc.cache.stats()["corrupt"] == 1
    qdir = tmp_path / "cache" / "quarantine"
    assert qdir.is_dir() and len(list(qdir.iterdir())) == 1


# ---------------------------------------------------------------------------
# compile_many subset + completion-callback API (the journal hooks)
# ---------------------------------------------------------------------------


def test_compile_many_points_subset_and_on_result(monkeypatch):
    monkeypatch.delenv(ENV_KEY, raising=False)
    tc = Toolchain((2, 2), CDCL)
    seen = []
    res = tc.compile_many(["bitcount", "reversebits"],
                          grids=[(2, 2), (2, 3)], jobs=1,
                          points=[("bitcount", 1), ("reversebits", 0)],
                          on_result=lambda pt, cr: seen.append(pt))
    assert [(c.kernel, c.size) for c in res] == \
        [("bitcount", "2x3"), ("reversebits", "2x2")]
    assert sorted(seen) == [("bitcount", 1), ("reversebits", 0)]
    with pytest.raises(ValueError, match="outside the kernels x grids"):
        tc.compile_many(["bitcount"], grids=[(2, 2)], points=[("nope", 0)])


# ---------------------------------------------------------------------------
# the sweep journal
# ---------------------------------------------------------------------------


def test_journal_round_trip_torn_tail_and_signature_mismatch(tmp_path):
    path = str(tmp_path / "j.jsonl")
    sig = {"kernels": ["a"], "backend": "cdcl"}
    j = SweepJournal(path)
    assert j.start(sig, resume=True) == {}  # no file yet -> fresh header
    j.record("a", "2x2", {"status": "mapped", "ii": 2})
    j.record("a", "2x3", {"status": "mapped", "ii": 3})
    j.record("a", "2x2", {"status": "mapped", "ii": 9})  # last wins
    j.close()
    with open(path, "a") as fh:
        fh.write('{"kernel": "a", "size": "3x3", "row": {"status"')  # torn
    done = SweepJournal(path).load(sig)
    assert done == {("a", "2x2"): {"status": "mapped", "ii": 9},
                    ("a", "2x3"): {"status": "mapped", "ii": 3}}
    # a different signature must not resume someone else's sweep
    assert SweepJournal(path).load({"kernels": ["b"]}) == {}
    j2 = SweepJournal(path)
    assert j2.start({"kernels": ["b"]}, resume=True) == {}  # rewritten
    j2.close()
    assert SweepJournal(path).load(sig) == {}


def test_sweep_journal_and_resume_skip_completed_points(tmp_path):
    cfg = SweepConfig(kernels=["bitcount", "reversebits"],
                      sizes=[(2, 2), (2, 3)], backend="cdcl",
                      per_point_timeout_s=30.0, per_ii_timeout_s=10.0,
                      jobs=1, cache_dir=None,
                      journal_path=str(tmp_path / "j.jsonl"))
    first = run_sweep(cfg)
    assert "resumed_points" not in first
    assert sum(1 for _ in open(cfg.journal_path)) == 5  # header + 4 rows
    # resume replays everything: no compile work, identical rows
    second = run_sweep(cfg, resume=True)
    assert second["resumed_points"] == 4
    assert second["points"] == first["points"]


def _projection(doc):
    keys = ("kernel", "size", "status", "ii", "utilization",
            "latency_cycles", "energy_nj", "cegar_rounds")
    return [{k: r.get(k) for k in keys} for r in doc["points"]]


def test_sweep_survives_chaos_kill_and_resumes_byte_identical(tmp_path):
    """The acceptance path: a chaotic sweep is hard-killed mid-run
    (``abort_after_points``), then ``--resume`` completes it; the
    correctness projection must equal a fault-free sweep's."""
    env_base = dict(os.environ, PYTHONPATH=SRC_DIR)
    env_base.pop(ENV_KEY, None)
    out = tmp_path / "dse.json"
    base_out = tmp_path / "base.json"
    args = [sys.executable, "-m", "repro.dse",
            "--kernels", "bitcount,reversebits", "--sizes", "2x2,2x3",
            "--backend", "cdcl", "--jobs", "2", "--timeout", "10"]

    # fault-free reference (its own cache so nothing is shared)
    p = subprocess.run(
        args + ["--cache-dir", str(tmp_path / "cache_base"),
                "--out", str(base_out), "--no-journal"],
        env=env_base, capture_output=True, text=True, timeout=120)
    assert p.returncode == 0, p.stderr
    args += ["--cache-dir", str(tmp_path / "cache")]

    # chaotic run, killed after 2 completed points
    chaos_env = dict(env_base)
    chaos_env[ENV_KEY] = json.dumps(
        {"seed": 3, "rate": 0.3, "abort_after_points": 2})
    p = subprocess.run(args + ["--out", str(out)], env=chaos_env,
                       capture_output=True, text=True, timeout=120)
    assert p.returncode == 23, (p.returncode, p.stderr)  # the chaos kill
    assert not out.exists()  # died before emitting the document
    journal = tmp_path / ".sweep_journal.jsonl"
    assert journal.exists()
    assert sum(1 for _ in open(journal)) == 3  # header + 2 durable rows

    # resume under the same chaos seed (minus the abort): completes and
    # converges to the fault-free answer
    chaos_env[ENV_KEY] = json.dumps({"seed": 3, "rate": 0.3})
    p = subprocess.run(args + ["--out", str(out), "--resume"],
                       env=chaos_env, capture_output=True, text=True,
                       timeout=120)
    assert p.returncode == 0, p.stderr
    doc = json.load(open(out))
    assert doc["resumed_points"] == 2
    assert doc["errors"] == 0
    base = json.load(open(base_out))
    assert _projection(doc) == _projection(base)


def test_cli_rejects_bad_chaos_spec():
    from repro.dse.cli import main as dse_main

    with pytest.raises(SystemExit):
        dse_main(["--chaos", '{"rate": "not json'])
    with pytest.raises(SystemExit):
        dse_main(["--chaos", '{"kinds": ["segfault"]}'])
