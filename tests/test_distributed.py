"""Distribution-layer tests on a forced host-device mesh (8 CPU devices).

Covers: sharding rules, checkpoint save/restore + atomic commit, elastic
resharding across mesh shapes, failure-injection restart, straggler
accounting, compressed collectives, the SAT-scheduled pipeline executor, and
deterministic data replay.
"""
import os
import sys
import subprocess
import textwrap

import pytest

pytest.importorskip("jax", reason="optional extra: pip install .[jax]")

SELF = os.path.abspath(__file__)


def run_worker(body: str) -> str:
    """Run a snippet in a subprocess with 8 forced host devices."""
    env = dict(os.environ)
    env["XLA_FLAGS"] = (env.get("XLA_FLAGS", "")
                        + " --xla_force_host_platform_device_count=8")
    env["PYTHONPATH"] = os.path.join(os.path.dirname(SELF), "..", "src")
    proc = subprocess.run([sys.executable, "-c", textwrap.dedent(body)],
                          capture_output=True, text=True, env=env,
                          timeout=600)
    assert proc.returncode == 0, proc.stderr[-3000:]
    return proc.stdout


def test_sharding_rules_cover_all_params():
    out = run_worker("""
        import jax
        from repro.configs import get_smoke
        from repro.models import Model
        from repro.parallel import sharding as shd
        mesh = jax.make_mesh((2, 4), ("data", "model"))
        for arch in ["llama3.2-3b", "granite-moe-3b-a800m", "mamba2-1.3b"]:
            model = Model(get_smoke(arch))
            shards = shd.param_shardings(model.defs, mesh, "fsdp_tp")
            n = len(jax.tree_util.tree_leaves(shards))
            n2 = len(jax.tree_util.tree_leaves(model.param_specs()))
            assert n == n2, (arch, n, n2)
        print("OK")
    """)
    assert "OK" in out


def test_train_two_steps_sharded_loss_decreases_finite():
    out = run_worker("""
        import jax, jax.numpy as jnp, numpy as np
        from repro.configs import get_smoke
        from repro.configs.base import RunConfig
        from repro.models import Model
        from repro.parallel import sharding as shd
        from repro.train.optimizer import init_opt_state
        from repro.train.train_step import make_train_step
        from repro.data.pipeline import DataConfig, SyntheticLM
        mesh = jax.make_mesh((2, 4), ("data", "model"))
        cfg = get_smoke("llama3.2-3b")
        model = Model(cfg, RunConfig(remat="none", attn_chunk=64,
                                     microbatches=2))
        params = model.init(jax.random.PRNGKey(0))
        opt = init_opt_state(params)
        data = SyntheticLM(DataConfig(vocab_size=cfg.vocab_size, seq_len=16,
                                      global_batch=8))
        step = make_train_step(model)
        with shd.set_mesh(mesh):
            pshard = shd.param_shardings(model.defs, mesh, "fsdp_tp")
            params = jax.device_put(params, pshard)
            jstep = jax.jit(step)
            losses = []
            for s in range(3):
                b = {k: jnp.asarray(v) for k, v in data.batch(s).items()}
                params, opt, metrics = jstep(params, opt, b)
                losses.append(float(metrics["loss"]))
        assert all(np.isfinite(l) for l in losses), losses
        assert losses[-1] < losses[0], losses
        print("OK", losses)
    """)
    assert "OK" in out


def test_checkpoint_restart_and_elastic_reshard(tmp_path):
    out = run_worker(f"""
        import jax, jax.numpy as jnp, numpy as np
        from repro.train.checkpoint import (latest_step, restore_checkpoint,
                                            save_checkpoint)
        from jax.sharding import NamedSharding, PartitionSpec as P
        d = {str(repr(str(tmp_path)))}
        tree = {{"w": jnp.arange(64, dtype=jnp.float32).reshape(8, 8),
                 "b": jnp.ones((4,))}}
        mesh8 = jax.make_mesh((8,), ("data",))
        sh8 = {{"w": NamedSharding(mesh8, P("data")),
                "b": NamedSharding(mesh8, P())}}
        tree = jax.device_put(tree, sh8)
        save_checkpoint(d, 7, tree)
        # restore onto a DIFFERENT mesh shape (elastic: 8 -> 2x4)
        mesh24 = jax.make_mesh((2, 4), ("data", "model"))
        sh24 = {{"w": NamedSharding(mesh24, P("model", "data")),
                 "b": NamedSharding(mesh24, P())}}
        restored, manifest = restore_checkpoint(d, tree, shardings=sh24)
        assert manifest["step"] == 7
        np.testing.assert_array_equal(np.asarray(restored["w"]),
                                      np.arange(64).reshape(8, 8))
        assert latest_step(d) == 7
        print("OK")
    """)
    assert "OK" in out


def test_fault_controller_restart_and_stragglers(tmp_path):
    out = run_worker(f"""
        import time
        import jax.numpy as jnp
        from repro.train.fault import (FaultConfig, TrainController,
                                       _InjectedFailure)
        ckdir = {str(repr(str(tmp_path / 'ck')))}
        state = {{"x": jnp.zeros(())}}
        calls = {{"n": 0}}
        def step(state, batch):
            calls["n"] += 1
            if calls["n"] == 12:
                time.sleep(0.25)      # one straggler step
            return {{"x": state["x"] + batch}}, {{"loss": float(state["x"])}}
        crashed = {{"done": False}}
        def failure_hook(step_idx):
            if step_idx == 7 and not crashed["done"]:
                crashed["done"] = True
                raise _InjectedFailure("boom")
        ctl = TrainController(FaultConfig(checkpoint_dir=ckdir,
                                          checkpoint_every=3),
                              step, lambda s: jnp.ones(()), failure_hook)
        state, report = ctl.run(state, 20)
        assert report.restarts == 1, report
        assert float(state["x"]) == 20.0, float(state["x"])  # replay exact
        print("OK", report.restarts, report.stragglers)
    """)
    assert "OK" in out


def test_compressed_psum_matches_exact_within_quantization():
    out = run_worker("""
        import jax, jax.numpy as jnp, numpy as np
        from jax.experimental.shard_map import shard_map
        from jax.sharding import PartitionSpec as P
        from repro.parallel import sharding as shd
        from repro.parallel.collectives import compressed_psum
        mesh = jax.make_mesh((8,), ("data",))
        x = jax.random.normal(jax.random.PRNGKey(0), (8, 128, 16))
        def body(v):
            return compressed_psum(v[0], "data")
        with shd.set_mesh(mesh):
            approx = shard_map(body, mesh=mesh, in_specs=P("data"),
                               out_specs=P())(x)
        exact = x.sum(0)
        rel = float(jnp.abs(approx - exact).max()
                    / jnp.abs(exact).max())
        assert rel < 0.05, rel
        print("OK", rel)
    """)
    assert "OK" in out


def test_pipeline_executor_matches_sequential():
    out = run_worker("""
        import jax, jax.numpy as jnp, numpy as np
        from repro.parallel import sharding as shd
        from repro.parallel.pipeline import pipeline_forward
        S, M, B, D = 4, 6, 2, 8
        mesh = jax.make_mesh((S,), ("stage",))
        key = jax.random.PRNGKey(0)
        ws = jax.random.normal(key, (S, D, D)) / np.sqrt(D)
        def stage_fn(w, x):
            return jnp.tanh(x @ w)
        micro = jax.random.normal(jax.random.PRNGKey(1), (M, B, D))
        with shd.set_mesh(mesh):
            run = pipeline_forward(mesh, stage_fn, ws, micro, S)
        # sequential reference
        ref = micro
        for s in range(S):
            ref = jax.vmap(lambda x: stage_fn(ws[s], x))(ref)
        np.testing.assert_allclose(np.asarray(run.outputs), np.asarray(ref),
                                   rtol=2e-5, atol=2e-5)
        assert run.num_ticks == M + S - 1
        print("OK", run.num_ticks)
    """)
    assert "OK" in out


def test_sat_schedule_reaches_1f1b_bound():
    from repro.core.pipeline_synth import (PipelineProblem, onef1b_ii_bound,
                                           synthesize)
    from repro.core import MapperConfig
    p = PipelineProblem(num_stages=4, stage_costs=[1, 1, 1, 1])
    sched = synthesize(p, MapperConfig(per_ii_timeout_s=60))
    assert sched.ii == 2 == onef1b_ii_bound(p)
    # every device runs exactly one F and one B per period (1F1B shape)
    for dev in range(4):
        blocks = [sched.table[r][dev] for r in range(sched.ii)]
        kinds = {b[0] for b in blocks if b}
        assert kinds == {"F", "B"}


def test_data_pipeline_determinism_and_host_sharding():
    import numpy as np
    from repro.data.pipeline import DataConfig, SyntheticLM
    full = SyntheticLM(DataConfig(vocab_size=97, seq_len=12, global_batch=8))
    h0 = SyntheticLM(DataConfig(vocab_size=97, seq_len=12, global_batch=8,
                                host_index=0, host_count=2))
    h1 = SyntheticLM(DataConfig(vocab_size=97, seq_len=12, global_batch=8,
                                host_index=1, host_count=2))
    b = full.batch(5)
    b0, b1 = h0.batch(5), h1.batch(5)
    np.testing.assert_array_equal(
        b["tokens"], np.concatenate([b0["tokens"], b1["tokens"]]))
    # replay determinism
    np.testing.assert_array_equal(b["tokens"], full.batch(5)["tokens"])
    assert not np.array_equal(b["tokens"], full.batch(6)["tokens"])
