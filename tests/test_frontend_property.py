"""Property test: random loop bodies from the traceable op set.

Hypothesis generates a small straight-line program over two accumulator
carries, the induction variable, loads, and the full traced op set
(arith/logic/shifts/selects/fxpmul, immediate and wide constants).  Each
program is built as a real Python body function, then checked two ways:

* trace -> legalize -> LoopBuilder *oracle* must agree with the concrete
  ``python_reference`` (pure Python, no SAT / no jax — this is the bulk of
  the examples);
* a few fixed descriptors additionally run the whole pipeline: SAT-map on
  a 3x3 CGRA and differentially co-simulate on the JAX PE-array.

Guarded like the PR-1 hypothesis suites: collection succeeds without the
``test`` extras installed.
"""
import numpy as np
import pytest

hypothesis = pytest.importorskip(
    "hypothesis", reason="optional extra: pip install .[test]")
from hypothesis import HealthCheck, given, settings  # noqa: E402
from hypothesis import strategies as st  # noqa: E402

from repro.frontend import (LoopSpec, MemRegion, fxpmul, legalize,
                            python_reference, trace_kernel, where)  # noqa: E402

MASK = (1 << 32) - 1

OPS = ("add", "sub", "mul", "and", "or", "xor", "shl_imm", "lshr_imm",
       "ashr_imm", "add_imm", "xor_imm", "select_lt", "select_eq", "load",
       "neg", "inv", "fxpmul", "min_like", "abs_like")

op_strategy = st.tuples(
    st.sampled_from(OPS),
    st.integers(0, 7),  # first operand (index into the value pool)
    st.integers(0, 7),  # second operand
    st.integers(-(2**17), 2**17),  # constant: spans the imm fit boundary
)

program_strategy = st.tuples(
    st.lists(op_strategy, min_size=1, max_size=8),
    st.integers(-(2**30), 2**30),  # init a
    st.integers(-(2**30), 2**30),  # init b
)


def make_body(descr):
    """Interpret one generated descriptor as a loop body function."""

    def body(s, mem):
        pool = [s.a, s.b, s.i, mem[s.i]]
        for op, i1, i2, k in descr:
            x = pool[i1 % len(pool)]
            y = pool[i2 % len(pool)]
            if op == "add":
                v = x + y
            elif op == "sub":
                v = x - y
            elif op == "mul":
                v = x * y
            elif op == "and":
                v = x & y
            elif op == "or":
                v = x | y
            elif op == "xor":
                v = x ^ y
            elif op == "shl_imm":
                v = x << (k % 8)
            elif op == "lshr_imm":
                v = x.lshr(k % 16)
            elif op == "ashr_imm":
                v = x >> (k % 16)
            elif op == "add_imm":
                v = x + k
            elif op == "xor_imm":
                v = x ^ k
            elif op == "select_lt":
                v = where(x < y, x, y)
            elif op == "select_eq":
                v = where(x == y, x + 1, y)
            elif op == "load":
                v = mem[s.i + (k % 32)]
            elif op == "neg":
                v = -x
            elif op == "inv":
                v = ~x
            elif op == "fxpmul":
                v = fxpmul(x, y)
            elif op == "min_like":
                v = where(x < k, x, k)
            else:  # abs_like
                v = where(x < 0, -x, x)
            pool.append(v)
        s.a = pool[-1]
        s.b = pool[-2] if len(pool) >= 2 else s.b
        mem[s.i + 64] = pool[-1]
        s.i = s.i + 1

    return body


def make_spec(init_a, init_b, name="prop"):
    return LoopSpec(
        name=name, trip=4, carries={"i": 0, "a": init_a, "b": init_b},
        results=("a", "b"),
        mem_regions=(MemRegion(0, 48, -(2**28), 2**28),))


def check_oracle_equivalence(descr, init_a, init_b, seeds=3):
    from repro.frontend.tracer import make_mem

    body = make_body(descr)
    spec = make_spec(init_a, init_b)
    prog = legalize(trace_kernel(spec, body), spec)
    for seed in range(seeds):
        mem = make_mem(spec, seed)
        ref_vals, ref_mem = python_reference(spec, body, mem)
        oracle_mem = [int(v) for v in mem]
        got = prog.run_oracle(oracle_mem)
        for kname, exp in ref_vals.items():
            assert (got[kname] & MASK) == (exp & MASK), (descr, seed, kname)
        assert [v & MASK for v in oracle_mem] == \
            [v & MASK for v in ref_mem], (descr, seed)


@settings(max_examples=60, deadline=None,
          suppress_health_check=[HealthCheck.too_slow])
@given(program_strategy)
def test_random_bodies_trace_legalize_to_oracle_equivalence(program):
    descr, init_a, init_b = program
    check_oracle_equivalence(descr, init_a, init_b)


# three fixed descriptors drive the full pipeline (SAT map + co-sim);
# chosen to cover selects, wide constants, and recurrence-heavy shapes —
# and verified mappable: a random body whose carry update sits shallower
# in the schedule than a next-iteration consumer violates the paper's C3
# hold window (separation > II) at every II, which is a legal trace but a
# structurally unmappable CIL
PIPELINE_CASES = [
    ([("add", 0, 3, 0), ("mul", 8, 2, 0), ("add_imm", 9, 0, 7)], 5, -3),
    ([("select_lt", 0, 3, 0), ("xor_imm", 4, 0, 0x5A5A5)], 100, 9),
    ([("shl_imm", 0, 0, 3), ("xor", 8, 0, 0), ("lshr_imm", 9, 0, 5)], 77, 1),
]


@pytest.mark.parametrize("case", range(len(PIPELINE_CASES)))
def test_random_body_full_pipeline_cosimulates(case):
    pytest.importorskip("jax", reason="optional extra: pip install .[jax]")
    from repro.cgra import make_grid
    from repro.cgra.simulator import map_for_execution, simulate
    from repro.core import MapperConfig, kms_ii_upper_bound
    from repro.frontend.tracer import make_mem

    descr, init_a, init_b = PIPELINE_CASES[case]
    body = make_body(descr)
    spec = make_spec(init_a, init_b, name=f"prop{case}")
    prog = legalize(trace_kernel(spec, body), spec)
    cfg = MapperConfig(per_ii_timeout_s=30, total_timeout_s=60, ii_max=32)
    res = map_for_execution(prog, make_grid(3, 3), cfg)
    if res.mapping is None:
        assert res.status == "timeout", res.status
        pytest.skip("mapping budget exhausted")
    assert res.mapping.ii <= kms_ii_upper_bound(prog.build_dfg(), 9)
    seeds = 4
    mems = np.stack([make_mem(spec, s) for s in range(seeds)])
    sim = simulate(prog, res.mapping, mems, batch=seeds)
    for b in range(seeds):
        ref_vals, ref_mem = python_reference(spec, body, mems[b])
        for kname, exp in ref_vals.items():
            node = prog.result_nodes[kname]
            assert (int(sim.node_values[node][b]) & MASK) == (exp & MASK)
        sim_mem = sim.final_mem[b].astype(np.int64) & MASK
        assert [int(v) for v in sim_mem] == [v & MASK for v in ref_mem]
