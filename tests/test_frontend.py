"""Traced front-end: tracer semantics, legalization, mapping, co-sim.

Layered so the expensive checks build on the cheap ones:

1. tracer unit tests (SSA recording, folding, rebinding, traceable-subset
   errors) — microseconds;
2. legalizer equivalence: trace -> legalize -> LoopBuilder *oracle* must
   match the concrete python_reference (no SAT, no jax);
3. the acceptance criterion: every shipped traced kernel SAT-maps on a
   4x4 CGRA at some II <= its KMS upper bound (pure-Python CDCL, no
   extras);
4. differential co-simulation: the mapped bitstream executed on the JAX
   PE-array agrees bit-exactly with the reference over 16 randomized
   inputs (needs the jax extra; skipped cleanly without it).
"""
import functools

import numpy as np
import pytest

from repro.cgra import make_grid
from repro.core import MapperConfig, kms_ii_upper_bound, validate_mapping
from repro.frontend import (TRACED_KERNELS, LoopSpec, MemRegion, TraceError,
                            absolute, fxpmul, legalize, python_reference,
                            trace_kernel, where)

MASK = (1 << 32) - 1

# budget per kernel: generous enough that every shipped kernel maps locally
# with time to spare; a grossly slower CI box degrades to skip via the
# explicit timeout status, never to a spurious failure
CFG = MapperConfig(per_ii_timeout_s=60, total_timeout_s=90, ii_max=32)


def spec_of(body, name="t", trip=4, carries=None, **kw):
    return LoopSpec(name=name, trip=trip, carries=carries or {"i": 0, "x": 7},
                    **kw)


def oracle_vs_reference(spec, body, mem):
    """Assert LoopBuilder-oracle == concrete-reference on one memory."""
    prog = legalize(trace_kernel(spec, body), spec)
    ref_vals, ref_mem = python_reference(spec, body, mem)
    oracle_mem = [int(v) for v in mem]
    got = prog.run_oracle(oracle_mem)
    for k, exp in ref_vals.items():
        assert (got[k] & MASK) == (exp & MASK), f"carry {k}"
    assert [v & MASK for v in oracle_mem] == [v & MASK for v in ref_mem]


# ---------------------------------------------------------------------------
# 1. tracer
# ---------------------------------------------------------------------------


def test_trace_records_ssa_and_carry_updates():
    def body(s, mem):
        s.x = s.x + mem[s.i] * 3
        s.i = s.i + 1

    tr = trace_kernel(spec_of(body), body)
    ops = tr.op_histogram()
    assert ops.get("load") == 1 and ops.get("mul") == 1
    assert ops.get("add") == 2 and ops.get("carry") == 2
    by_name = {c.name: c for c in tr.carries}
    assert by_name["x"].update is not None
    assert by_name["i"].update != by_name["i"].leaf  # i was rewritten


def test_read_after_write_sees_new_value():
    """Python rebinding semantics: the second statement reads the new x."""

    def body(s, mem):
        s.x = s.x + 1
        s.i = s.x * 2  # must observe x+1, not the carried x

    spec = spec_of(body, carries={"i": 0, "x": 10}, trip=1)
    vals, _ = python_reference(spec, body, np.zeros(16, np.int32))
    assert vals["x"] == 11 and vals["i"] == 22
    oracle_vs_reference(spec, body, np.zeros(16, np.int32))


def test_constant_folding_and_cse():
    def body(s, mem):
        a = mem[s.i] + mem[s.i]  # CSE: identical loads become one node
        b = s.x * 1  # identity: no mul emitted
        c = b & -1  # identity: no and emitted
        s.x = a + c
        s.i = s.i + 1

    tr = trace_kernel(spec_of(body), body)
    ops = tr.op_histogram()
    assert ops.get("load", 0) == 1
    assert "mul" not in ops and "and" not in ops
    assert ops.get("add") == 3  # a, the x update, the i increment


def test_untraceable_constructs_raise():
    def branchy(s, mem):
        if s.x > 0:  # noqa: data-dependent branch must raise
            s.x = s.x - 1

    with pytest.raises(TraceError, match="control flow"):
        trace_kernel(spec_of(branchy), branchy)

    def floaty(s, mem):
        s.x = s.x + 1.5

    with pytest.raises(TraceError, match="integers"):
        trace_kernel(spec_of(floaty), floaty)

    def divides(s, mem):
        s.x = s.x / 2

    with pytest.raises(TraceError, match="divider"):
        trace_kernel(spec_of(divides), divides)

    def undeclared(s, mem):
        s.y = 1

    with pytest.raises(TraceError, match="undeclared carry"):
        trace_kernel(spec_of(undeclared), undeclared)

    def cond_as_data(s, mem):
        s.x = (s.x < 3) + 1

    with pytest.raises(TraceError, match="comparison"):
        trace_kernel(spec_of(cond_as_data), cond_as_data)


def test_where_requires_condition():
    def body(s, mem):
        s.x = where(s.x, 1, 0)  # data value, not a comparison

    with pytest.raises(TraceError, match="comparison"):
        trace_kernel(spec_of(body), body)


# ---------------------------------------------------------------------------
# 2. legalizer
# ---------------------------------------------------------------------------


def test_immediates_fold_into_the_consumer():
    def body(s, mem):
        s.x = s.x + 5
        s.i = s.i + 1

    spec = spec_of(body)
    prog = legalize(trace_kernel(spec, body), spec)
    adds = [n for n in prog.nodes if n.op == "SADD"]
    assert any(prog.node_imm[n.id] == 5 for n in adds)
    # no constant was materialized: both constants fit the imm slot
    assert not any(c.name.startswith("_const_") for c in prog.carries)


def test_wide_constants_materialize_as_const_carries():
    def body(s, mem):
        s.x = (s.x & 0x55555555) ^ 0x33333333
        s.i = s.i + 1

    spec = spec_of(body, carries={"i": 0, "x": -123456789})
    prog = legalize(trace_kernel(spec, body), spec)
    consts = [c for c in prog.carries if c.name.startswith("_const_")]
    assert len(consts) == 2
    assert sorted(c.init for c in consts) == [0x33333333, 0x55555555]
    oracle_vs_reference(spec, body, np.zeros(16, np.int32))


@pytest.mark.parametrize("cmp_name", ["lt", "le", "gt", "ge", "eq", "ne"])
def test_select_lowering_every_comparison(cmp_name):
    cmp_fn = {
        "lt": lambda a, b: a < b, "le": lambda a, b: a <= b,
        "gt": lambda a, b: a > b, "ge": lambda a, b: a >= b,
        "eq": lambda a, b: a == b, "ne": lambda a, b: a != b,
    }[cmp_name]

    def body(s, mem):
        a = mem[s.i]
        b = mem[s.i + 8]
        s.x = where(cmp_fn(a, b), a - b, b - a)
        s.i = s.i + 1

    spec = spec_of(body, trip=8)
    prog = legalize(trace_kernel(spec, body), spec)
    assert any(n.op in ("BSFA", "BZFA") for n in prog.nodes)
    rng = np.random.RandomState(3)
    mem = np.zeros(32, np.int32)
    mem[:16] = rng.randint(-100, 100, 16)
    mem[4] = mem[12]  # force an equal pair so eq/ne/le/ge edges are hit
    oracle_vs_reference(spec, body, mem)


def test_flag_producer_duplicated_per_select():
    """Two selects on one compare need two flag producers: the PE flag
    register only holds the most recent result (same-PE, nothing between)."""

    def body(s, mem):
        c = s.x > 0
        s.x = where(c, s.x - 1, s.x)
        s.i = where(c, s.i + 1, s.i)

    spec = spec_of(body, carries={"i": 0, "x": 5})
    prog = legalize(trace_kernel(spec, body), spec)
    dfg = prog.build_dfg()  # DFG construction rejects shared flag producers
    flags = [e for e in dfg.edges if e.kind == "flag"]
    assert len(flags) == 2
    assert len({e.src for e in flags}) == 2
    oracle_vs_reference(spec, body, np.zeros(16, np.int32))


def test_neg_invert_and_logical_shift():
    def body(s, mem):
        v = mem[s.i]
        s.x = (-v ^ ~v) + v.lshr(3)
        s.i = s.i + 1

    spec = spec_of(body, trip=8)
    rng = np.random.RandomState(11)
    mem = np.zeros(32, np.int32)
    mem[:8] = rng.randint(-(2**30), 2**30, 8)
    oracle_vs_reference(spec, body, mem)


def test_const_address_load_and_store():
    """a = mem[5] lowers to LWI with the ZERO source: address = 0 + imm —
    also pins the programs.py oracle fix for absent LWI/SWI operands."""

    def body(s, mem):
        s.x = s.x + mem[5]
        mem[40] = s.x
        mem[41] = 0
        s.i = s.i + 1

    spec = spec_of(body)
    prog = legalize(trace_kernel(spec, body), spec)
    lwis = [n for n in prog.nodes if n.op == "LWI"]
    assert len(lwis) == 1
    assert prog.node_srcs[lwis[0].id][0] is None
    assert prog.node_imm[lwis[0].id] == 5
    mem = np.zeros(64, np.int32)
    mem[5] = 1234
    oracle_vs_reference(spec, body, mem)


def test_loop_control_appends_exit_branch():
    def body(s, mem):
        s.x = s.x + 1
        s.i = s.i + 1

    spec = spec_of(body, trip=6, index="i", loop_control=True)
    prog = legalize(trace_kernel(spec, body), spec)
    ops = [n.op for n in prog.nodes]
    assert "BNE" in ops and "JUMP" in ops
    bne = next(n for n in prog.nodes if n.op == "BNE")
    assert prog.node_imm[bne.id] == 6
    oracle_vs_reference(spec, body, np.zeros(16, np.int32))


def test_loop_invariant_carry_becomes_constant():
    """An unwritten carry is a loop constant: MOV self-loop, preset-seeded."""

    def body(s, mem):
        s.acc = s.acc + s.k
        s.i = s.i + 1

    spec = spec_of(body, carries={"i": 0, "acc": 0, "k": 0x12345678},
                   results=("acc",))
    prog = legalize(trace_kernel(spec, body), spec)
    dfg = prog.build_dfg()
    mov_ids = {n.id for n in prog.nodes if n.op == "MOV"}
    self_loops = {e.src for e in dfg.edges
                  if e.src == e.dst and e.distance == 1}
    assert mov_ids & self_loops, "expected a MOV self-loop constant carry"
    vals, _ = python_reference(spec, body, np.zeros(8, np.int32))
    assert vals["acc"] == 4 * 0x12345678  # fits int32, no wrap
    oracle_vs_reference(spec, body, np.zeros(8, np.int32))


# ---------------------------------------------------------------------------
# 3. shipped kernels: oracle equivalence + the mapping acceptance criterion
# ---------------------------------------------------------------------------

ALL_TRACED = sorted(TRACED_KERNELS)


@pytest.mark.parametrize("name", ALL_TRACED)
def test_traced_kernel_oracle_matches_reference(name):
    tk = TRACED_KERNELS[name]
    prog = tk.build()
    for seed in range(16):
        mem = tk.make_mem(seed)
        ref_vals, ref_mem = tk.reference([int(v) for v in mem])
        oracle_mem = [int(v) for v in mem]
        got = prog.run_oracle(oracle_mem)
        for k, exp in ref_vals.items():
            assert (got[k] & MASK) == (exp & MASK), (name, seed, k)
        assert [v & MASK for v in oracle_mem] == \
            [v & MASK for v in ref_mem], (name, seed)


@functools.lru_cache(maxsize=None)
def _map_on_4x4(name):
    from repro.cgra.simulator import map_for_execution

    tk = TRACED_KERNELS[name]
    program = tk.build()
    res = map_for_execution(program, make_grid(4, 4), CFG)
    return program, res


@pytest.mark.parametrize("name", ALL_TRACED)
def test_traced_kernel_maps_within_kms_bound(name):
    """Acceptance criterion: II <= KMS upper bound on a 4x4 CGRA."""
    program, res = _map_on_4x4(name)
    if res.mapping is None:
        # an exhausted budget on a slow box is a skip; UNSAT is a real
        # front-end regression and must fail
        assert res.status == "timeout", (name, res.status)
        pytest.skip(f"{name}: mapping budget exhausted ({res.status})")
    bound = kms_ii_upper_bound(program.build_dfg(), 16)
    assert res.mapping.ii <= bound, (name, res.mapping.ii, bound)
    assert validate_mapping(res.mapping) == []


@pytest.mark.parametrize("name", ALL_TRACED)
def test_traced_kernel_cosimulates_bit_exactly(name):
    """Differential co-sim vs the Python reference over 16 random inputs."""
    pytest.importorskip("jax", reason="optional extra: pip install .[jax]")
    from repro.frontend.verify import cosimulate

    program, res = _map_on_4x4(name)
    if res.mapping is None:
        assert res.status == "timeout", (name, res.status)
        pytest.skip(f"{name}: mapping budget exhausted ({res.status})")
    # reuse the harness end to end (it re-maps from its own budget when
    # given one; pass the shared config so the result is the cached II)
    rep = cosimulate(TRACED_KERNELS[name], seeds=16, config=CFG)
    assert rep.status == "ok", (name, rep.status, rep.mismatches[:4])
    assert rep.seeds == 16


def test_run_all_map_only_reports_every_kernel():
    from repro.frontend.verify import run_all

    doc = run_all(kernels=["dotprod", "xorshift32"], execute=False,
                  config=CFG)
    assert doc["summary"]["total"] == 2
    for rep in doc["kernels"]:
        assert rep["status"] in ("mapped", "timeout"), rep
        if rep["status"] == "mapped":
            assert rep["ii"] <= rep["ii_bound"]


# ---------------------------------------------------------------------------
# 4. registry integration
# ---------------------------------------------------------------------------


def test_traced_kernels_join_the_shared_registry():
    from repro.cgra.registry import kernel_names, kernel_program, make_mem

    names = kernel_names()
    assert "gsm" in names and "dotprod" in names  # both origins present
    assert set(kernel_names(origin="traced")) == set(ALL_TRACED)
    prog = kernel_program("dotprod")
    assert prog.build_dfg().num_nodes > 0
    assert make_mem("dotprod", 0).shape == (128,)


def test_dse_space_sweeps_traced_kernels():
    from repro.dse.space import DEFAULT_KERNELS, build_space

    assert set(ALL_TRACED) <= set(DEFAULT_KERNELS)
    pts = build_space(["dotprod", "gsm"], [(2, 2), (3, 3)])
    assert len(pts) == 4
    with pytest.raises(ValueError, match="unknown kernels"):
        build_space(["nope"], [(2, 2)])
