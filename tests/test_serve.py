"""Mapping-as-a-service: wire protocol (versioned schema, golden
fixtures), server-side bookkeeping (in-flight dedup, tenant budgets),
the asyncio compile server end to end over TCP and stdio, and the
deprecation shims of the CLI unification.

Solving runs on the dependency-free CDCL backend over 2x2 grids with
``inline=True`` worker threads, so the whole module stays inside tier-1
time budgets."""

import asyncio
import json
import os
import subprocess
import sys
import threading

import pytest

from repro.core import MapperConfig
from repro.core.dfg import running_example
from repro.serve import (
    CompileRequest,
    CompileServer,
    InflightCompiles,
    ProtocolError,
    ServeClient,
    ServeError,
    ServeStats,
    TenantBudgets,
    request_sync,
    wire_source,
)
from repro.serve.protocol import decode, encode
from repro.toolchain import CompileResult, Toolchain
from repro.toolchain.artifacts import WireMapResult

CDCL = MapperConfig(backend="cdcl", per_ii_timeout_s=10.0,
                    total_timeout_s=30.0)

FIXTURES = os.path.join(os.path.dirname(os.path.abspath(__file__)),
                        "fixtures")
SRC_DIR = os.path.join(
    os.path.dirname(os.path.dirname(os.path.abspath(__file__))), "src")

# summary() keys that legitimately differ between service paths (a
# cache replay flips cache_hit, wall times move) — everything else is
# the correctness projection that must be identical
VOLATILE = ("stage_times_s", "cache_hit", "cancelled_after_s")


def _projection(summary):
    return {k: v for k, v in summary.items() if k not in VOLATILE}


def _canon(doc):
    return json.dumps(doc, sort_keys=True, separators=(",", ":"))


# ---------------------------------------------------------------------------
# protocol: schema, encode/decode, golden fixtures
# ---------------------------------------------------------------------------


def test_encode_decode_round_trip_and_errors():
    msg = {"type": "compile", "request_id": "r1", "b": [1, None]}
    assert decode(encode(msg)) == msg
    assert encode(msg).endswith(b"\n")
    with pytest.raises(ProtocolError):
        decode(b"not json\n")
    with pytest.raises(ProtocolError):
        decode(b"[1, 2]\n")  # frames must be objects


def test_wire_source_lowers_every_source_kind():
    assert wire_source("bitcount") == "bitcount"
    dfg = running_example()
    d = wire_source(dfg)
    assert d == dfg.to_dict() and wire_source(d) == d
    with pytest.raises(ProtocolError):
        wire_source(42)


def test_compile_request_round_trip_and_version_gate():
    req = CompileRequest(source="bitcount", arch="2x2",
                         config={"ii_max": 8}, strategy=None, priority=3,
                         tenant="alice", request_id="r9")
    back = CompileRequest.from_dict(json.loads(json.dumps(req.to_dict())))
    assert back == req
    bad = dict(req.to_dict(), v=99)
    with pytest.raises(ProtocolError, match="version"):
        CompileRequest.from_dict(bad)


def test_mapper_config_merge_and_strategy_override():
    base = MapperConfig(backend="cdcl", ii_max=32)
    req = CompileRequest(source="bitcount", config={"ii_max": 8})
    cfg = req.mapper_config(base)
    assert cfg.backend == "cdcl" and cfg.ii_max == 8
    raced = CompileRequest(source="bitcount",
                           strategy="portfolio:cdcl-seq+cdcl-pair")
    rcfg = raced.mapper_config(base)
    assert rcfg.strategy == "portfolio:cdcl-seq+cdcl-pair"
    assert rcfg.backend == "auto" and rcfg.amo is None
    with pytest.raises(ProtocolError, match="unknown MapperConfig"):
        CompileRequest(source="bitcount",
                       config={"nope": 1}).mapper_config(base)


def test_golden_request_fixture_round_trips():
    # the committed wire frame must keep parsing (schema stability) and
    # re-serialize byte-identically (no silent field drift)
    with open(os.path.join(FIXTURES, "wire_compile_request.json")) as fh:
        fixture = json.load(fh)
    req = CompileRequest.from_dict(fixture)
    assert _canon(req.to_dict()) == _canon(fixture)
    assert req == CompileRequest(
        source=fixture["source"], arch=fixture["arch"],
        config=fixture["config"], strategy=fixture["strategy"],
        priority=fixture["priority"], tenant=fixture["tenant"],
        request_id=fixture["request_id"])


def test_golden_result_fixture_round_trips():
    # both directions of the result schema: the committed to_dict()
    # document revives context-free, re-serializes byte-identically and
    # yields the committed digest
    with open(os.path.join(FIXTURES, "wire_compile_result.json")) as fh:
        fixture = json.load(fh)
    cr = CompileResult.from_dict(fixture["result"])
    assert _canon(cr.to_dict()) == _canon(fixture["result"])
    assert cr.summary() == fixture["summary"]
    assert isinstance(cr.map_result, WireMapResult)
    assert cr.mapping.utilization == fixture["summary"]["utilization"]


def test_golden_result_fixture_matches_fresh_compile():
    with open(os.path.join(FIXTURES, "wire_compile_result.json")) as fh:
        fixture = json.load(fh)
    cr = Toolchain("2x2", CDCL).compile("bitcount")
    assert _projection(cr.summary()) == _projection(fixture["summary"])


# ---------------------------------------------------------------------------
# queue bookkeeping
# ---------------------------------------------------------------------------


def test_inflight_coalescing_bookkeeping():
    inflight = InflightCompiles()
    assert inflight.join("k1", "a") is True  # leader
    assert inflight.join("k1", "b") is False
    assert inflight.join("k2", "c") is True
    assert inflight.depth("k1") == 2 and len(inflight) == 2
    assert inflight.pop("k1") == ["a", "b"]
    assert inflight.pop("k1") == [] and len(inflight) == 1


def test_tenant_budgets_admit_release():
    budgets = TenantBudgets(2)
    assert budgets.admit("a") and budgets.admit("a")
    assert not budgets.admit("a")  # at budget
    assert budgets.admit("b")  # budgets are per-tenant
    budgets.release("a")
    assert budgets.admit("a")
    assert budgets.snapshot() == {"a": 2, "b": 1}
    unlimited = TenantBudgets(None)
    assert all(unlimited.admit("x") for _ in range(100))


def test_serve_stats_snapshot():
    stats = ServeStats()
    stats.received += 3
    stats.compiled += 1
    stats.coalesced += 2
    assert stats.snapshot() == {
        "received": 3, "compiled": 1, "cache_hits": 0, "coalesced": 2,
        "rejected": 0, "errors": 0}


def test_stats_schema_bump_is_additive_over_v1_golden():
    """STATS_SCHEMA 2 only *adds* fields: every key of the golden v1
    ``stats`` body survives, same name and same JSON type, so clients
    written against v1 keep parsing new servers unchanged."""
    with open(os.path.join(FIXTURES, "wire_stats_v1.json")) as fh:
        golden = json.load(fh)
    golden.pop("_comment")

    async def body(server, client):
        cr, served = await client.compile("bitcount")
        assert served == "compiled"
        return await client.stats()

    stats = asyncio.run(_with_server(body))

    def check_additive(g, s, path="stats"):
        for key, val in g.items():
            assert key in s, f"{path}.{key} dropped from stats response"
            assert type(s[key]) is type(val), \
                f"{path}.{key} changed type {type(val).__name__} -> " \
                f"{type(s[key]).__name__}"
            if isinstance(val, dict):
                check_additive(val, s[key], f"{path}.{key}")

    check_additive(golden, stats)
    # a v1 client's exact read patterns still work on the live response
    assert stats["v"] == 1
    assert stats["serving"]["compiled"] == 1
    assert stats["mapper_invocations"] == 1
    # the bump is advertised; new telemetry lives under *new* keys only
    assert stats["stats_schema"] >= CompileServer.STATS_SCHEMA
    assert set(stats["metrics"]) == {"counters", "histograms"}
    assert stats["queue"] == {"pool_pending": 0, "inflight_keys": 0}
    assert stats["metrics"]["counters"]["serve.served.compiled"] == 1
    lat = stats["metrics"]["histograms"]["serve.request_s"]
    assert lat["count"] == 1 and {"p50", "p90", "p99"} <= set(lat)
    # per-stage latency histograms cover the served pipeline stages
    # (the server parses sources itself, so no "source" stage here)
    stages = {k for k in stats["metrics"]["histograms"]
              if k.startswith("serve.stage.")}
    assert {"serve.stage.map_s", "serve.stage.assemble_s",
            "serve.stage.metrics_s"} <= stages


# ---------------------------------------------------------------------------
# the server end to end (in-process TCP)
# ---------------------------------------------------------------------------


async def _with_server(body, **server_kw):
    server_kw.setdefault("inline", True)
    server = CompileServer("2x2", CDCL, **server_kw)
    try:
        host, port = await server.start()
        client = await ServeClient.connect(host, port)
        try:
            return await body(server, client)
        finally:
            await client.close()
    finally:
        server.close()


def test_server_result_matches_direct_toolchain_compile(tmp_path):
    # the acceptance contract: a served result is byte-identical in
    # correctness projection to the same compile run directly
    async def body(server, client):
        cr, served = await client.compile("bitcount", arch="2x2")
        assert served == "compiled"
        return cr

    cr = asyncio.run(_with_server(body))
    direct = Toolchain("2x2", CDCL).compile("bitcount")
    assert _projection(cr.summary()) == _projection(direct.summary())
    assert cr.ok and cr.ii == direct.ii


def test_concurrent_identical_requests_coalesce(monkeypatch):
    # N identical concurrent requests -> exactly one mapper invocation,
    # N identical results.  The (counted) solver blocks until every
    # request has joined the in-flight group, so coalescing is proven
    # deterministically, not raced.
    from repro.toolchain import resilience
    real = resilience._run_map_payload
    calls = []
    release = threading.Event()

    def counting(payload, inline=False, cancel=None):
        calls.append(payload["kernel"])
        release.wait(timeout=30)
        return real(payload, inline=inline, cancel=cancel)

    monkeypatch.setattr(resilience, "_run_map_payload", counting)
    N = 5

    async def body(server, client):
        tasks = [asyncio.ensure_future(client.compile("bitcount"))
                 for _ in range(N)]
        for _ in range(500):
            if (len(server.inflight) == 1
                    and server.inflight.depth(
                        next(iter(server.inflight._waiters))) == N):
                break
            await asyncio.sleep(0.01)
        else:
            pytest.fail("requests never coalesced onto one key")
        release.set()
        out = await asyncio.gather(*tasks)
        assert server.mapper_invocations == 1
        assert sorted(s for _, s in out) == \
            ["coalesced"] * (N - 1) + ["compiled"]
        projections = {_canon(_projection(cr.summary())) for cr, _ in out}
        assert len(projections) == 1
        stats = await client.stats()
        assert stats["serving"]["received"] == N
        assert stats["serving"]["compiled"] == 1
        assert stats["serving"]["coalesced"] == N - 1
        return None

    asyncio.run(_with_server(body, jobs=2))
    assert calls == ["bitcount"]


def test_high_priority_jumps_the_low_priority_flood(monkeypatch):
    # with one worker slot, a flood of queued low-priority work may cost
    # a high-priority request at most the one compile already in flight
    from repro.toolchain import resilience
    real = resilience._run_map_payload
    calls = []
    gate = threading.Semaphore(0)

    def gated(payload, inline=False, cancel=None):
        calls.append(payload["cfg"]["ii_max"])
        gate.acquire()
        return real(payload, inline=inline, cancel=cancel)

    monkeypatch.setattr(resilience, "_run_map_payload", gated)
    lows = [8, 9, 10, 11]  # distinct ii_max -> distinct cache keys
    high = 30

    async def body(server, client):
        tasks = [asyncio.ensure_future(client.compile(
            "bitcount", config={"ii_max": m}, priority=0)) for m in lows]
        for _ in range(500):  # first low must occupy the only slot
            if calls:
                break
            await asyncio.sleep(0.01)
        assert calls == [lows[0]]
        tasks.append(asyncio.ensure_future(client.compile(
            "bitcount", config={"ii_max": high}, priority=5)))
        for _ in range(500):  # the late request must be enqueued
            if server.inflight.depth(
                    next(iter(reversed(server.inflight._waiters)))):
                break
            await asyncio.sleep(0.01)
        for _ in range(len(lows) + 1):
            gate.release()
        out = await asyncio.gather(*tasks)
        assert all(cr.ok for cr, _ in out)
        return None

    asyncio.run(_with_server(body, jobs=1))
    # the high-priority compile ran right after the one in flight
    assert calls[0] == lows[0] and calls[1] == high
    assert sorted(calls[2:]) == sorted(lows[1:])


def test_duplicate_after_completion_is_served_from_cache(tmp_path):
    async def body(server, client):
        first, served1 = await client.compile("bitcount")
        second, served2 = await client.compile("bitcount")
        assert (served1, served2) == ("compiled", "cache")
        assert server.mapper_invocations == 1
        assert second.cache_hit and not first.cache_hit
        assert _projection(second.summary()) == \
            _projection(first.summary())
        stats = await client.stats()
        assert stats["serving"]["cache_hits"] == 1
        assert stats["cache"]["hits"] == 1
        return None

    asyncio.run(_with_server(body, cache=str(tmp_path / "cache")))


def test_tenant_budget_rejects_excess_inflight(monkeypatch):
    from repro.toolchain import resilience
    real = resilience._run_map_payload
    release = threading.Event()

    def blocking(payload, inline=False, cancel=None):
        release.wait(timeout=30)
        return real(payload, inline=inline, cancel=cancel)

    monkeypatch.setattr(resilience, "_run_map_payload", blocking)

    async def body(server, client):
        first = asyncio.ensure_future(
            client.compile("bitcount", tenant="alice"))
        for _ in range(500):
            if len(server.inflight):
                break
            await asyncio.sleep(0.01)
        # same tenant over budget -> typed rejection; others unaffected
        with pytest.raises(ServeError, match="admission budget"):
            await client.compile("reversebits", tenant="alice")
        other = asyncio.ensure_future(
            client.compile("reversebits", tenant="bob"))
        release.set()
        (cr1, _), (cr2, _) = await asyncio.gather(first, other)
        assert cr1.ok and cr2.ok
        stats = await client.stats()
        assert stats["serving"]["rejected"] == 1
        # budgets drain once answered: alice can compile again
        cr3, served = await client.compile("bitcount", tenant="alice")
        assert cr3.ok and served == "compiled"
        return None

    asyncio.run(_with_server(body, tenant_budget=1))


def test_unknown_kernel_is_a_typed_error_not_a_crash():
    async def body(server, client):
        with pytest.raises(ServeError, match="unknown kernel"):
            await client.compile("no_such_kernel")
        resp = await client.submit("no_such_kernel")
        assert resp["type"] == "error"
        stats = await client.stats()
        assert stats["serving"]["errors"] == 2
        cr, _ = await client.compile("bitcount")  # connection survives
        assert cr.ok
        return None

    asyncio.run(_with_server(body))


def test_bare_dfg_request_keeps_toolchain_semantics():
    # a wire DFG is map-only: same contract as Toolchain.compile(dfg) —
    # the mapping rides on map_result, status records the assemble stop
    async def body(server, client):
        cr, served = await client.compile(running_example(), arch="2x2")
        assert served == "compiled"
        return cr

    cr = asyncio.run(_with_server(body))
    direct = Toolchain("2x2", CDCL).compile(running_example())
    assert cr.status == "error" and cr.stage == "assemble"
    assert cr.map_result.status == "mapped"
    assert cr.ii == direct.ii
    assert _projection(cr.summary()) == _projection(direct.summary())


def test_request_sync_and_server_shutdown(tmp_path):
    started = threading.Event()
    info = {}

    def serve():
        async def go():
            server = CompileServer("2x2", CDCL, inline=True,
                                   cache=str(tmp_path / "cache"))
            try:
                host, port = await server.start()
                info.update(host=host, port=port)
                started.set()
                await server.wait_closed()
            finally:
                server.close()

        asyncio.run(go())

    t = threading.Thread(target=serve, daemon=True)
    t.start()
    assert started.wait(20)
    resp = request_sync("bitcount", info["host"], info["port"])
    assert resp["type"] == "result" and resp["served"] == "compiled"
    cr = CompileResult.from_dict(resp["result"])
    assert cr.ok
    resp2 = request_sync("bitcount", info["host"], info["port"],
                         shutdown=True)
    assert resp2["served"] == "cache"
    t.join(timeout=20)
    assert not t.is_alive()


# ---------------------------------------------------------------------------
# CLI integration: stdio serving, deprecation shims
# ---------------------------------------------------------------------------


def _env():
    env = dict(os.environ)
    env["PYTHONPATH"] = SRC_DIR + os.pathsep + env.get("PYTHONPATH", "")
    return env


def test_serve_stdio_subprocess_end_to_end():
    async def go():
        proc = await asyncio.create_subprocess_exec(
            sys.executable, "-m", "repro", "serve", "--stdio",
            "--arch", "2x2", "--backend", "cdcl", "--inline",
            "--jobs", "1", "--timeout", "30",
            stdin=asyncio.subprocess.PIPE, stdout=asyncio.subprocess.PIPE,
            stderr=asyncio.subprocess.DEVNULL, env=_env())
        try:
            client = await ServeClient.over_streams(proc.stdout,
                                                    proc.stdin)
            assert client.hello["arch"] == "2x2"
            cr, served = await client.compile("bitcount", arch="2x2")
            assert cr.ok and served == "compiled"
            await client.shutdown()
            await client.close()
            await asyncio.wait_for(proc.wait(), timeout=30)
            assert proc.returncode == 0
        finally:
            if proc.returncode is None:
                proc.kill()
                await proc.wait()

    asyncio.run(asyncio.wait_for(go(), timeout=120))


@pytest.mark.parametrize("module,canonical", [
    ("repro.dse", "sweep"),
    ("repro.frontend", "cosim"),
])
def test_deprecated_entry_points_warn_and_forward(module, canonical):
    # the shim warns but forwards verbatim to the canonical subcommand
    out = subprocess.run(
        [sys.executable, "-m", module, "--help"],
        capture_output=True, text=True, env=_env(), timeout=60)
    assert out.returncode == 0
    assert "deprecated" in out.stderr
    assert f"python -m repro {canonical}" in out.stderr
    # escalating the warning blocks the run before any work happens
    hard = subprocess.run(
        [sys.executable, "-W", "error::DeprecationWarning", "-m", module,
         "--help"], capture_output=True, text=True, env=_env(),
        timeout=60)
    assert hard.returncode != 0
    assert "DeprecationWarning" in hard.stderr
