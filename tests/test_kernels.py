"""Pallas PE-array kernel vs pure-jnp oracle: shape/value sweeps.

The Pallas kernel runs in interpret mode (CPU container; TPU is the target).
"""
import numpy as np
import pytest

pytest.importorskip("hypothesis", reason="optional extra: pip install .[test]")
pytest.importorskip("jax", reason="optional extra: pip install .[jax]")
from hypothesis import HealthCheck, given, settings, strategies as st

import jax.numpy as jnp

from repro.cgra import make_grid
from repro.cgra.isa import DST_NONE, Instr, OPCODE, OPS, encode_program
from repro.cgra.simulator import neighbor_table
from repro.kernels.ops import decode_fields, init_state, run_program
from repro.kernels.pe_array import cycle_step_pallas
from repro.kernels.ref import InstrRow, PEState, cycle_step_ref

ALU_OPS = ["SADD", "SSUB", "SMUL", "SLT", "SRT", "SRA", "LAND", "LOR",
           "LXOR", "LNAND", "LNOR", "LXNOR", "BSFA", "BZFA", "BEQ", "MOV",
           "NOP", "LWI", "SWI"]


def random_fields(rng, T, P):
    """Random program; memory ops get collision-free immediate addresses
    (simultaneous same-address stores are UB per the kernels/ref.py
    contract — the mapper can never schedule them)."""
    from repro.cgra.isa import SRC_ZERO
    rows = []
    for t in range(T):
        row = []
        for p in range(P):
            op = rng.choice(ALU_OPS)
            imm = int(rng.randint(0, 64))
            src_a = int(rng.randint(0, 11))
            if op in ("LWI", "SWI"):
                imm = (t * P + p) % 64     # unique address per (t, p)
                src_a = SRC_ZERO
            row.append(Instr(op=op, dst=int(rng.randint(0, 5)) % 4
                             if rng.random() < .7 else DST_NONE,
                             src_a=src_a,
                             src_b=int(rng.randint(0, 11)),
                             imm=imm))
        rows.append(row)
    return rows


@pytest.mark.parametrize("rows_cols,batch,M", [
    ((2, 2), 1, 64), ((2, 2), 8, 128), ((3, 3), 4, 128),
    ((4, 4), 2, 256), ((5, 5), 3, 128),
])
def test_pallas_matches_ref_random_programs(rows_cols, batch, M):
    rng = np.random.RandomState(hash(rows_cols) % 1000 + batch)
    grid = make_grid(*rows_cols)
    P = grid.num_pes
    T = 12
    rows = random_fields(rng, T, P)
    fields = decode_fields(encode_program(rows))
    mem = rng.randint(0, 2**20, size=(batch, M)).astype(np.int32)
    state = init_state(batch, P, mem)
    # seed register/out state so operands are non-trivial
    state = state._replace(
        regs=jnp.asarray(rng.randint(-2**10, 2**10, state.regs.shape),
                         jnp.int32),
        out=jnp.asarray(rng.randint(-2**10, 2**10, state.out.shape),
                        jnp.int32))
    nbrs = neighbor_table(grid)
    f_ref, o_ref = run_program(fields, state, nbrs, backend="ref")
    f_pal, o_pal = run_program(fields, state, nbrs, backend="pallas",
                               interpret=True)
    np.testing.assert_array_equal(np.asarray(o_ref), np.asarray(o_pal))
    for a, b in zip(f_ref, f_pal):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


@given(st.integers(0, 10_000))
@settings(deadline=None, max_examples=10,
          suppress_health_check=[HealthCheck.too_slow])
def test_pallas_matches_ref_property(seed):
    rng = np.random.RandomState(seed)
    grid = make_grid(2, 2)
    rows = random_fields(rng, 6, 4)
    fields = decode_fields(encode_program(rows))
    state = init_state(2, 4, rng.randint(0, 2**16, size=(2, 64)))
    nbrs = neighbor_table(grid)
    f_ref, o_ref = run_program(fields, state, nbrs, backend="ref")
    f_pal, o_pal = run_program(fields, state, nbrs, backend="pallas")
    np.testing.assert_array_equal(np.asarray(o_ref), np.asarray(o_pal))
    np.testing.assert_array_equal(np.asarray(f_ref.mem), np.asarray(f_pal.mem))


def test_isa_encode_decode_roundtrip():
    rng = np.random.RandomState(0)
    for _ in range(200):
        ins = Instr(op=str(rng.choice(OPS)), dst=int(rng.randint(0, 8)),
                    src_a=int(rng.randint(0, 11)),
                    src_b=int(rng.randint(0, 11)),
                    imm=int(rng.randint(-2**15, 2**15)))
        assert Instr.decode(ins.encode()) == ins


def test_single_op_semantics_vs_scalar_oracle():
    """Each ALU op on the array == isa.alu_semantics scalarly."""
    from repro.cgra.isa import alu_semantics
    grid = make_grid(2, 2)
    nbrs = neighbor_table(grid)
    rng = np.random.RandomState(3)
    for op in ["SADD", "SSUB", "SMUL", "SLT", "SRT", "SRA", "LAND", "LOR",
               "LXOR", "LNAND", "LNOR", "LXNOR", "BEQ"]:
        a = int(rng.randint(-2**20, 2**20))
        b = int(rng.randint(0, 31)) if op in ("SLT", "SRT", "SRA") \
            else int(rng.randint(-2**20, 2**20))
        rows = [[Instr(op=op, dst=0, src_a=1, src_b=2, imm=0)] * 4]
        fields = decode_fields(encode_program(rows))
        state = init_state(1, 4, np.zeros((1, 16), np.int32))
        regs = np.zeros((1, 4, 4), np.int32)
        regs[:, :, 1] = a
        regs[:, :, 2] = b
        state = state._replace(regs=jnp.asarray(regs))
        final, _ = run_program(fields, state, nbrs, backend="ref")
        got = int(np.asarray(final.out)[0, 0])
        exp = alu_semantics(op, a, b)
        assert got == exp, (op, a, b, got, exp)
