"""Portfolio racer, Strategy API and cross-point fact store.

Covers the PR's acceptance contract end to end: the strategy/portfolio
grammar and its deprecation shims (single-strategy cache keys stay
byte-identical to the legacy backend/amo pair), prompt cooperative
cancellation of an in-flight CDCL search, the RaceBook's
order-independent lowest-II-wins commit rule (driven with adversarial
completion orders), portfolio-vs-sequential II equivalence over the
kernel registry (inline and on the forked fleet), the fact-lifting
soundness condition with an end-to-end mesh-4x4 -> mesh-6x6 witness,
and a chaos-crashed racing worker healing to the sequential answer.

Everything runs on the dependency-free CDCL strategies so the module
stays in tier-1 time budgets without the z3 extra.
"""
import json
import threading
import time
from types import SimpleNamespace

import pytest

from repro.cgra import make_grid
from repro.core import MapperConfig
from repro.core.backends import (NAMED_STRATEGIES, PortfolioSpec, Strategy,
                                 parse_portfolio, parse_strategy,
                                 resolve_portfolio)
from repro.core.dfg import running_example
from repro.core.facts import (FactStore, embeds_in, grid_meta, remap_combo,
                              seed_from_jsonable, seed_to_jsonable)
from repro.core.mapper import IIOutcome, attempt_ii, mapping_cache_key
from repro.core.portfolio import RaceBook
from repro.core.schedule import Slot, asap_alap
from repro.core.mii import min_ii
from repro.sat.cdcl import INTERRUPTED, CDCLSolver
from repro.sat.cnf import CNF
from repro.toolchain import Toolchain
from repro.toolchain.chaos import ENV_KEY, ChaosSpec
from repro.toolchain.cli import main as repro_main

CDCL = MapperConfig(backend="cdcl", per_ii_timeout_s=10.0,
                    total_timeout_s=30.0)
PORTFOLIO = "portfolio:cdcl-seq+cdcl-pair,spec_ii=2"

# fast (kernel, grid) points spanning both registry origins; all map in
# well under a second on CDCL (see benchmarks/portfolio.py for timings)
EQUIV_CASES = [
    ("bitcount", (2, 2)),
    ("reversebits", (2, 2)),
    ("dotprod", (3, 3)),
    ("saxpy", (2, 2)),
    ("relu_clamp", (2, 2)),
    ("xorshift32", (3, 3)),
    ("gsm", (2, 2)),
    ("prefix_sum", (3, 3)),
    ("popcount", (3, 3)),
]


def _portfolio_cfg(**kw):
    return MapperConfig(strategy=PORTFOLIO, per_ii_timeout_s=10.0,
                        total_timeout_s=30.0, **kw)


# ---------------------------------------------------------------------------
# strategy / portfolio grammar
# ---------------------------------------------------------------------------


def test_named_strategies_roundtrip():
    for name in NAMED_STRATEGIES:
        assert parse_strategy(name).name == name


def test_bare_backend_and_auto_parse():
    assert parse_strategy("cdcl") == Strategy("cdcl")
    assert parse_strategy("auto").backend in ("cdcl", "z3")
    with pytest.raises(ValueError, match="unknown strategy"):
        parse_strategy("minisat")


def test_default_amo_spellings_compare_equal():
    # an explicitly-passed backend-default AMO normalizes to None, so the
    # two spellings hash/compare/cache-key identically
    s = Strategy("cdcl")
    assert Strategy("cdcl", s.resolved_amo) == s


def test_parse_portfolio_roundtrip_and_defaults():
    spec = parse_portfolio(PORTFOLIO)
    assert [s.name for s in spec.strategies] == ["cdcl-seq", "cdcl-pair"]
    assert spec.spec_ii == 2
    assert spec.to_compact() == PORTFOLIO
    assert parse_portfolio(spec.to_compact()) == spec
    # the portfolio: form defaults to spec_ii=2 (II and II+1 in flight)
    assert parse_portfolio("portfolio:cdcl-seq+cdcl-pair").spec_ii == 2
    # a bare strategy name is the degenerate single sequential spec
    bare = parse_portfolio("cdcl-seq")
    assert bare.is_single_sequential and bare.spec_ii == 1
    assert bare.to_compact() == "cdcl-seq"


def test_parse_portfolio_auto_roster_is_available():
    spec = parse_portfolio("portfolio:auto")
    assert len(spec.strategies) >= 2  # the two CDCL strategies at minimum
    assert all(s.available() for s in spec.strategies)


def test_portfolio_grammar_errors():
    with pytest.raises(ValueError, match="duplicate"):
        parse_portfolio("portfolio:cdcl-seq+cdcl-seq")
    with pytest.raises(ValueError, match="spec_ii"):
        parse_portfolio("portfolio:cdcl-seq+cdcl-pair,spec_ii=0")
    with pytest.raises(ValueError, match="key=value"):
        parse_portfolio("portfolio:cdcl-seq,spec_ii")
    with pytest.raises(ValueError, match="empty portfolio"):
        parse_portfolio("portfolio:")


def test_resolve_portfolio_shim_and_conflict():
    # legacy backend/amo pair resolves to a single sequential strategy
    legacy = resolve_portfolio(None, backend="cdcl", amo=None)
    assert legacy.is_single_sequential
    assert legacy.strategies[0] == Strategy("cdcl")
    # setting both surfaces is ambiguous and must raise
    with pytest.raises(ValueError, match="conflicts"):
        resolve_portfolio("cdcl-seq", backend="cdcl")
    with pytest.raises(ValueError, match="conflicts"):
        resolve_portfolio("cdcl-seq", backend="auto", amo="pairwise")


def test_mapper_config_accepts_typed_objects():
    spec = parse_portfolio(PORTFOLIO)
    assert MapperConfig(strategy=spec).strategy == PORTFOLIO
    assert (MapperConfig(strategy=Strategy("cdcl")).strategy
            == Strategy("cdcl").name)


# ---------------------------------------------------------------------------
# cache keys: the deprecation-shim byte-identity contract
# ---------------------------------------------------------------------------


def test_cache_keys_frozen_for_legacy_configs():
    """Literal pre-Strategy-API hashes: any drift invalidates every
    content-addressed cache entry in the wild, so these are frozen."""
    dfg, g = running_example(), make_grid(2, 2)
    assert mapping_cache_key(dfg, g) == (
        "691e2fa0e72eb46483b9251b54d339a0aa44fb56135680cc15d0f2383e9bbb8d")
    assert mapping_cache_key(dfg, g, MapperConfig(backend="cdcl")) == (
        "691e2fa0e72eb46483b9251b54d339a0aa44fb56135680cc15d0f2383e9bbb8d")
    assert mapping_cache_key(
        dfg, g, MapperConfig(backend="cdcl", amo="sequential")) == (
        "ead26430423a96298fc3103f9a2fcfd47ee73bf6cfca80a1e90486a2990a694b")
    assert mapping_cache_key(
        dfg, g, MapperConfig(backend="cdcl"),
        extra="oracle=bitstream-prologue") == (
        "867c32fca10042fdfac95d0a8bf18935bd8868a2eda7a4522b94bb8eda11e3a2")


def test_cache_key_single_strategy_matches_legacy_pair():
    dfg, g = running_example(), make_grid(2, 2)
    assert (mapping_cache_key(dfg, g, MapperConfig(strategy="cdcl-seq"))
            == mapping_cache_key(dfg, g, MapperConfig(backend="cdcl")))
    # a real portfolio keys differently (it is a different computation)
    assert (mapping_cache_key(dfg, g, MapperConfig(strategy=PORTFOLIO))
            != mapping_cache_key(dfg, g, MapperConfig(backend="cdcl")))


# ---------------------------------------------------------------------------
# cooperative interruption
# ---------------------------------------------------------------------------


def _pigeonhole_cnf(pigeons: int, holes: int) -> CNF:
    """PHP(n, n-1): small to state, exponentially hard for CDCL."""
    cnf = CNF()
    v = {(p, h): cnf.new_var()
         for p in range(pigeons) for h in range(holes)}
    for p in range(pigeons):
        cnf.add_clause([v[p, h] for h in range(holes)])
    for h in range(holes):
        for p1 in range(pigeons):
            for p2 in range(p1 + 1, pigeons):
                cnf.add_clause([-v[p1, h], -v[p2, h]])
    return cnf


def test_cdcl_interrupt_lands_promptly_mid_search():
    # PHP(9,8) runs for >30s uninterrupted; the conflict-loop cancel
    # check must land within a couple hundred milliseconds
    solver = CDCLSolver(_pigeonhole_cnf(9, 8))
    threading.Timer(0.1, solver.interrupt).start()
    t0 = time.monotonic()
    assert solver.solve(timeout_s=30.0) == INTERRUPTED
    assert time.monotonic() - t0 < 2.0


def test_cdcl_stop_hook_and_interrupt_flag_reset():
    solver = CDCLSolver(_pigeonhole_cnf(9, 8))
    assert solver.solve(timeout_s=30.0, stop=lambda: True) == INTERRUPTED
    # the flag is per-call: a stale interrupt must not poison this solve
    solver2 = CDCLSolver(_pigeonhole_cnf(4, 4))
    solver2.interrupt()
    solver2._interrupt = False
    assert solver2.solve(timeout_s=10.0) == "sat"


def test_attempt_ii_reports_interrupted_verdict():
    from repro.cgra.registry import kernel_program

    dfg = kernel_program("gsm").build_dfg()
    grid = make_grid(2, 2)
    ms = asap_alap(dfg)
    ii = min_ii(dfg, grid.num_pes)
    out = attempt_ii(dfg, grid, ms, ii, CDCL, parse_strategy("cdcl-seq"),
                     blocked=[], stop=lambda: True)
    assert out.verdict == "interrupted"
    assert out.mapping is None and not out.proven_unsat


# ---------------------------------------------------------------------------
# RaceBook: order-independent lowest-II-wins commit rule
# ---------------------------------------------------------------------------

SPEC2 = parse_portfolio(PORTFOLIO)  # 2 strategies, spec_ii=2


def _mapped(ii):
    return IIOutcome(ii=ii, verdict="mapped",
                     mapping=SimpleNamespace(ii=ii))


def _advance(ii, proven=False):
    return IIOutcome(ii=ii, verdict="advance", proven_unsat=proven)


def test_racebook_speculative_ii_plus_one_waits_for_lower_rung():
    """II+1 finishing (mapped!) first must not commit anything until the
    lower rung is decided — then the lowest feasible II wins."""
    book = RaceBook(SPEC2, start_ii=3, ii_max=10)
    book.record(4, 0, _mapped(4))       # primary maps II=4 first
    assert book.resolution() is None    # II=3 still open: no commit
    book.record(3, 0, _advance(3))      # primary advances II=3
    assert book.resolution() == ("mapped", 4)


def test_racebook_lower_rung_mapping_beats_earlier_higher_win():
    book = RaceBook(SPEC2, start_ii=3, ii_max=10)
    book.record(4, 0, _mapped(4))       # speculative II+1 wins early...
    book.record(3, 0, _mapped(3))       # ...but II=3 turns out feasible
    assert book.resolution() == ("mapped", 3)
    assert book.mapped[3][1].mapping.ii == 3


def test_racebook_nonprimary_mapped_is_telemetry_only():
    """A racer's SAT witness must never decide a rung (the primary could
    still RA-fail it — two opposite-sign verdicts would make the result
    arrival-order-dependent)."""
    book = RaceBook(SPEC2, start_ii=3, ii_max=10)
    book.record(3, 1, _mapped(3))
    assert book.resolution() is None
    assert 3 not in book.decided
    book.record(3, 0, _advance(3))      # primary overrules: advance
    book.record(4, 0, _mapped(4))
    assert book.resolution() == ("mapped", 4)


def test_racebook_proven_unsat_from_any_strategy_decides():
    """UNSAT is a fact about the solution space, not about who searched
    it — a non-primary proof advances the rung immediately."""
    book = RaceBook(SPEC2, start_ii=3, ii_max=10)
    book.record(3, 1, _advance(3, proven=True))
    assert book.decided[3] == "advance"
    book.record(4, 0, _mapped(4))
    assert book.resolution() == ("mapped", 4)


def test_racebook_order_independence_exhaustive():
    """Every completion order of the same four events commits the same
    II (the determinism contract, brute-forced).  The event set must be
    *realizable* — a SAT witness and an UNSAT proof at one II cannot
    coexist, which is exactly why proven UNSAT is safe to take from any
    strategy."""
    import itertools

    events = [(3, 0, _advance(3)), (3, 1, _advance(3, proven=True)),
              (4, 0, _mapped(4)), (4, 1, _mapped(4))]
    outcomes = set()
    for order in itertools.permutations(range(4)):
        book = RaceBook(SPEC2, start_ii=3, ii_max=10)
        for i in order:
            ii, sidx, out = events[i]
            book.record(ii, sidx, out)
        outcomes.add(book.resolution())
    assert outcomes == {("mapped", 4)}


def test_racebook_interrupted_keeps_rung_open():
    book = RaceBook(SPEC2, start_ii=3, ii_max=10)
    book.record(3, 0, IIOutcome(ii=3, verdict="interrupted"))
    assert (3, 0) not in book.completed
    assert (3, 0) in [t for t in book.wanted()]  # still worth running
    assert book.resolution() is None


def test_racebook_known_unsat_predecides_and_window_skips():
    book = RaceBook(SPEC2, start_ii=3, ii_max=10, known_unsat=(3, 4))
    assert book.window() == [5, 6]
    book.record(5, 0, _mapped(5))
    assert book.resolution() == ("mapped", 5)


def test_racebook_moot_and_cancellation_targets():
    book = RaceBook(SPEC2, start_ii=3, ii_max=10)
    book.record(3, 0, _mapped(3))
    assert book.moot(3) and book.moot(4)   # everything above a win is moot
    assert book.wanted() == []


def test_racebook_primary_loss_settles_on_lowest_index_survivor():
    book = RaceBook(SPEC2, start_ii=3, ii_max=10)
    book.record_lost(3, 0)                 # primary crashed out
    assert book.resolution() is None       # racer still running
    book.record(3, 1, _mapped(3))
    assert book.resolution() == ("mapped", 3)
    # all strategies lost -> the parent must solve the rung inline
    book2 = RaceBook(SPEC2, start_ii=3, ii_max=10)
    book2.record_lost(3, 0)
    book2.record_lost(3, 1)
    assert book2.needs_inline() == 3


def test_racebook_unsat_capped_resolution():
    book = RaceBook(SPEC2, start_ii=3, ii_max=4)
    book.record(3, 0, _advance(3))
    book.record(4, 0, _advance(4))
    assert book.resolution() == ("unsat-capped", None)


# ---------------------------------------------------------------------------
# portfolio == sequential II over the registry
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("kernel,size", EQUIV_CASES,
                         ids=[f"{k}@{r}x{c}" for k, (r, c) in EQUIV_CASES])
def test_portfolio_commits_sequential_ii(kernel, size):
    seq = Toolchain(size, CDCL).map(kernel)
    port = Toolchain(size, _portfolio_cfg()).map(kernel, jobs=1)
    assert seq.status == port.status == "mapped"
    assert port.ii == seq.ii
    assert not port.validation_errors  # validate_mapping-clean
    assert port.strategies_raced >= 1
    assert port.winner


def test_portfolio_fleet_race_matches_sequential():
    seq = Toolchain((2, 2), CDCL).map("gsm")
    port = Toolchain((2, 2), _portfolio_cfg()).map("gsm", jobs=2)
    assert port.status == "mapped" and port.ii == seq.ii
    assert port.winner
    assert port.strategies_raced >= 2  # a real race, not the inline path


# ---------------------------------------------------------------------------
# fact store: lifting condition, remapping, end-to-end witness
# ---------------------------------------------------------------------------


def _meta(rows, cols, topo="mesh", regs=4, fp=None):
    return (rows, cols, topo, regs, fp)


def test_embeds_in_matrix():
    assert embeds_in(_meta(2, 2), _meta(3, 3))       # mesh grows: ok
    assert embeds_in(_meta(2, 3), _meta(2, 3))       # identity: ok
    assert not embeds_in(_meta(3, 3), _meta(2, 3))   # shrinking: no
    # torus wrap edges are not preserved by widening -> never lift
    assert not embeds_in(_meta(2, 2, topo="torus"), _meta(3, 3, topo="torus"))
    assert not embeds_in(_meta(2, 2, topo="torus"), _meta(3, 3))
    # register-file mismatch breaks register-pressure facts
    assert not embeds_in(_meta(2, 2, regs=4), _meta(3, 3, regs=8))
    # heterogeneous fabrics tie facts to specific PEs
    assert not embeds_in(_meta(2, 2, fp="abc"), _meta(3, 3))
    # ... but the *exact* same architecture always transfers verbatim
    assert embeds_in(_meta(2, 2, topo="torus", fp="abc"),
                     _meta(2, 2, topo="torus", fp="abc"))


def test_grid_meta_reflects_real_grids():
    g = make_grid(3, 2)
    rows, cols, topo, regs, fp = grid_meta(g)
    assert (rows, cols) == (3, 2)
    assert regs == g.spec.num_regs


def test_remap_combo_reindexes_row_major():
    combo = [(0, 3, Slot(1, 0)), (1, 2, Slot(0, 1))]
    # 2-wide mesh: PE 3 = (1,1), PE 2 = (1,0); 3-wide: -> 4 and 3
    out = remap_combo(combo, src_cols=2, dst_cols=3)
    assert [(n, p) for (n, p, _) in out] == [(0, 4), (1, 3)]
    assert out[0][2] == Slot(1, 0)  # slots are untouched
    assert remap_combo(combo, 2, 2) == combo


def test_fact_store_publish_lift_directions():
    store = FactStore()
    dfg = running_example()
    small = make_grid(2, 2, torus=False)
    big = make_grid(3, 3, torus=False)
    combo = [(0, 1, Slot(0, 0)), (1, 3, Slot(1, 0))]
    res_small = SimpleNamespace(blocked_combos=[combo], unsat_iis=[2],
                                status="mapped",
                                mapping=SimpleNamespace(ii=3))
    assert store.publish(dfg, small, "assembler", res_small) == 3
    # publishing the identical facts again is a no-op (dedup)
    assert store.publish(dfg, small, "assembler", res_small) == 0

    # combos + feasible-II lift UP to the bigger grid
    seed_up = store.lift(dfg, big, "assembler")
    assert seed_up["ii_cap"] == 3
    assert seed_up["blocked"] == [remap_combo(combo, 2, 3)]
    # ... UNSAT does not (it was proven on the smaller grid)
    assert seed_up["unsat_iis"] == []

    # UNSAT lifts DOWN: publish on the big grid, lift onto the small one
    res_big = SimpleNamespace(blocked_combos=[], unsat_iis=[1],
                              status="unsat-capped", mapping=None)
    store.publish(dfg, big, "assembler", res_big)
    seed_down = store.lift(dfg, small, "assembler")
    assert 1 in seed_down["unsat_iis"]
    # combos proven on the big grid do not lift down
    assert seed_down["blocked"] == [combo]  # only the small grid's own

    # facts are keyed by oracle tag: a different oracle sees nothing
    assert store.lift(dfg, big, "other-oracle") is None


def test_fact_seed_json_roundtrip():
    seed = {"blocked": [[(0, 1, Slot(0, 0)), (2, 3, Slot(1, 1))]],
            "unsat_iis": [2, 3], "ii_cap": 4}
    assert seed_from_jsonable(seed_to_jsonable(seed)) == seed
    assert seed_to_jsonable(None) is None
    assert seed_from_jsonable(None) is None


def test_fact_lifting_end_to_end_mesh4x4_to_6x6():
    """The ISSUE's soundness witness: facts proven on mesh-4x4 seed the
    mesh-6x6 solve, which must still commit the same II as a cold run."""
    store = FactStore()
    r4 = Toolchain("mesh-4x4", CDCL, facts=store).map("gsm")
    assert r4.status == "mapped"
    assert store.published >= 1
    seeded = Toolchain("mesh-6x6", CDCL, facts=store).map("gsm")
    cold = Toolchain("mesh-6x6", CDCL).map("gsm")
    assert seeded.status == cold.status == "mapped"
    assert seeded.ii == cold.ii
    assert seeded.facts_used >= 1          # the lift actually happened
    assert store.lifted >= 1
    assert cold.facts_used == 0            # and cold runs don't see it


def test_fact_seeded_results_never_enter_the_cache(tmp_path):
    """The cache key cannot see the seed, so a seeded result must not be
    written back (it could shadow a differently-seeded future run)."""
    from repro.dse.cache import MappingCache

    store = FactStore()
    cache = MappingCache(str(tmp_path / "cache"))
    Toolchain("mesh-4x4", CDCL, facts=store).map("gsm")
    tc6 = Toolchain("mesh-6x6", CDCL, cache=cache, facts=store)
    res = tc6.map("gsm")
    assert res.facts_used >= 1
    assert cache.stats()["misses"] >= 1
    # a fresh session over the same cache must miss (nothing was put)
    tc6b = Toolchain("mesh-6x6", CDCL, cache=cache)
    tc6b.map("gsm")
    assert not tc6b.last_cache_hit


# ---------------------------------------------------------------------------
# chaos: a crash-injected racing worker heals to the sequential answer
# ---------------------------------------------------------------------------


def test_chaos_crashed_racing_worker_heals(monkeypatch):
    seq = Toolchain((2, 2), CDCL).map("gsm")
    spec = ChaosSpec(seed=11, rate=1.0, kinds=("crash",), attempts=(0,))
    monkeypatch.setenv(ENV_KEY, spec.to_json())
    port = Toolchain((2, 2), _portfolio_cfg()).map("gsm", jobs=2)
    assert port.status == "mapped"
    assert port.ii == seq.ii


# ---------------------------------------------------------------------------
# CLI surface + digest telemetry
# ---------------------------------------------------------------------------


def test_cli_map_strategy_emits_race_telemetry(capsys):
    rc = repro_main(["map", "gsm", "--grid", "2x2",
                     "--strategy", PORTFOLIO, "--jobs", "1", "--json"])
    assert rc == 0
    doc = json.loads(capsys.readouterr().out)
    assert doc["status"] == "ok"
    assert doc["strategies_raced"] >= 1
    assert doc["winner"]


def test_sequential_digest_has_no_portfolio_fields(capsys):
    """Baseline byte-identity: a plain sequential digest must not grow
    any of the new telemetry keys."""
    rc = repro_main(["map", "bitcount", "--grid", "2x2",
                     "--backend", "cdcl", "--json"])
    assert rc == 0
    doc = json.loads(capsys.readouterr().out)
    for key in ("strategies_raced", "winner", "cancelled_after_s",
                "facts_used"):
        assert key not in doc


def test_cli_strategy_backend_conflict_fails():
    rc = repro_main(["map", "bitcount", "--grid", "2x2",
                     "--backend", "cdcl", "--strategy", "cdcl-seq"])
    assert rc != 0


def test_dse_rows_carry_race_telemetry_only_when_racing():
    from repro.dse.sweep import SweepConfig, run_sweep

    base = dict(kernels=["bitcount"], sizes=[(2, 2)], cache_dir=None,
                per_point_timeout_s=30.0, per_ii_timeout_s=10.0, jobs=1)
    plain = run_sweep(SweepConfig(backend="cdcl", **base))
    raced = run_sweep(SweepConfig(strategy=PORTFOLIO, **base))
    prow, rrow = plain["points"][0], raced["points"][0]
    assert "strategies_raced" not in prow
    assert rrow["strategies_raced"] >= 1 and rrow["winner"]
    assert rrow["ii"] == prow["ii"]


def test_sweep_share_facts_lifts_across_points():
    from repro.dse.sweep import SweepConfig, run_sweep

    cfg = SweepConfig(kernels=["gsm"], sizes=[(2, 2), (3, 3)],
                      backend="cdcl", share_facts=True, cache_dir=None,
                      per_point_timeout_s=30.0, per_ii_timeout_s=10.0,
                      jobs=1)
    doc = run_sweep(cfg)
    assert all(r["status"] == "mapped" for r in doc["points"])
    # signature() gates the new knobs on non-default values so existing
    # journals keep resuming
    assert "share_facts" in cfg.signature()
    assert "share_facts" not in SweepConfig(backend="cdcl").signature()
