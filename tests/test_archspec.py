"""Heterogeneous architecture subsystem: spec grammar, topology edge
behavior, capability/port SAT constraints, independent validation, cache
keys, and the toolchain/DSE threading."""
import json

import pytest

from repro.archspec import (ArchSpec, ArchSpecError, PRESETS, load_arch,
                            parse_arch)
from repro.cgra.arch import ArchCaps, make_grid
from repro.cgra.energy import FULL_PE_AREA, arch_area, pe_area
from repro.core.backends import solve_cdcl
from repro.core.dfg import DFG, Edge, Node
from repro.core.mapper import MapperConfig, map_dfg, mapping_cache_key
from repro.core.mapping import Mapping, Placement, validate_mapping
from repro.core.sat_encoding import KMSEncoding
from repro.core.schedule import Slot, asap_alap, fold_kms
from repro.toolchain import Toolchain

CDCL = MapperConfig(backend="cdcl", per_ii_timeout_s=15.0,
                    total_timeout_s=30.0, ii_max=20)


# ---------------------------------------------------------------------------
# spec grammar + serialization
# ---------------------------------------------------------------------------


def test_compact_string_round_trip():
    spec = parse_arch("mesh-4x4:mem=col0,regs=8,ports=1/row")
    assert spec.topology == "mesh"
    assert spec.num_regs == 8
    assert spec.mem_pes() == frozenset({0, 4, 8, 12})
    assert spec.port_groups()[0] == ("row0", frozenset({0, 1, 2, 3}), 1)
    assert parse_arch(spec.to_compact()) == spec


def test_bare_geometry_is_homogeneous_torus():
    spec = parse_arch("4x4")
    assert spec == ArchSpec(4, 4)
    assert spec.is_homogeneous
    assert spec.to_compact() == "torus-4x4"


def test_selector_unions_and_explicit_pes():
    spec = parse_arch("torus-4x4:mem=col0+col3,mul=pe5.6")
    assert spec.mem_pes() == frozenset({0, 4, 8, 12, 3, 7, 11, 15})
    assert spec.mul_pes() == frozenset({5, 6})
    border = parse_arch("torus-3x3:mem=border")
    assert border.mem_pes() == frozenset(range(9)) - {4}


@pytest.mark.parametrize("bad", [
    "ring-4x4",                      # unknown topology
    "torus-4",                       # no RxC
    "torus-4x4:mem=col9",            # column out of range
    "torus-4x4:mem=diag0",           # unknown selector
    "torus-4x4:ports=1/pe",          # unknown scope
    "torus-4x4:frobnicate=1",        # unknown option
])
def test_malformed_specs_raise(bad):
    with pytest.raises(ArchSpecError):
        parse_arch(bad)


def test_json_document_round_trip(tmp_path):
    spec = PRESETS["bordermem-4x4"]
    p = tmp_path / "arch.json"
    p.write_text(json.dumps(spec.to_dict()))
    assert load_arch(str(p)) == spec


def test_dict_rejects_unknown_fields():
    with pytest.raises(ArchSpecError):
        ArchSpec.from_dict({"rows": 4, "cols": 4, "wings": 2})


def test_arch_hash_is_content_addressed():
    named = PRESETS["bordermem-4x4"]
    anon = parse_arch("torus-4x4:mem=border,ports=1/col")
    assert named.name and not anon.name
    assert named.arch_hash() == anon.arch_hash()
    assert named.arch_hash() != parse_arch("torus-4x4:mem=border").arch_hash()


# ---------------------------------------------------------------------------
# topology edge behavior (mesh / diagonal / one-hop)
# ---------------------------------------------------------------------------


def test_mesh_neighbors_do_not_wrap():
    g = parse_arch("mesh-3x3").grid()
    assert g.neighbors(0) == frozenset({1, 3})          # corner: 2 links
    assert g.neighbors(1) == frozenset({0, 2, 4})       # edge: 3 links
    assert g.neighbors(4) == frozenset({1, 3, 5, 7})    # interior: 4 links
    t = make_grid(3, 3)  # torus: every PE has 4 neighbors
    assert all(len(t.neighbors(p)) == 4 for p in range(9))


def test_mesh_f_n_edge_behavior():
    g = parse_arch("mesh-3x3").grid()
    assert g.f_n(0, 0) == 1
    assert g.f_n(0, 1) == 2
    assert g.f_n(0, 2) == 0       # two hops on the mesh
    assert g.f_n(0, 6) == 0       # would be a wraparound link on the torus
    assert make_grid(3, 3).f_n(0, 6) == 2


def test_mesh_reachable_pairs_asymmetric_degrees():
    """reachable_pairs stays symmetric as a relation, but border PEs
    appear in fewer pairs than interior ones (no wraparound)."""
    g = parse_arch("mesh-3x3").grid()
    pairs = set(g.reachable_pairs())
    assert all((q, p) in pairs for (p, q) in pairs)
    def degree(p):
        return sum(1 for (a, b) in pairs if a == p and b != p)
    assert degree(0) == 2 < degree(1) == 3 < degree(4) == 4
    t = make_grid(3, 3)
    assert len(t.reachable_pairs()) == 9 * 5  # uniform on the torus
    assert len(pairs) == 9 + 2 * 12           # self-pairs + 12 mesh links


def test_diagonal_and_one_hop_links():
    d = parse_arch("diag-4x4").grid()
    assert d.neighbors(5) == frozenset({0, 1, 2, 4, 6, 8, 9, 10})
    o = parse_arch("onehop-4x4").grid()
    assert o.neighbors(0) == frozenset({1, 2, 4, 8})
    assert not d.assemblable and not o.assemblable
    assert make_grid(4, 4).assemblable


# ---------------------------------------------------------------------------
# symmetry breaking auto-disables off the homogeneous torus
# ---------------------------------------------------------------------------


def _encode(dfg, grid, ii, **kw):
    return KMSEncoding(dfg, fold_kms(asap_alap(dfg), ii), grid, **kw)


def _chain(n=4):
    nodes = [Node(i, op="SADD") for i in range(1, n + 1)]
    edges = [Edge(i, i + 1) for i in range(1, n)]
    return DFG(nodes, edges, name="chain")


@pytest.mark.parametrize("arch,expect", [
    ("torus-3x3", True),                        # homogeneous torus: sound
    ("mesh-3x3", False),                        # mesh: not vertex transitive
    ("diag-4x4", False),
    ("torus-3x3:mem=col0", False),              # caps make PEs distinct
    ("openedge-3x3", False),                    # port table does too
])
def test_symmetry_break_auto_disable(arch, expect):
    grid = parse_arch(arch).grid()
    assert grid.is_vertex_transitive() is expect
    enc = _encode(_chain(), grid, ii=2, symmetry_break=True)
    assert enc.symmetry_break is expect
    assert bool(enc.forced_false) is expect


def test_symmetry_break_on_mesh_still_sat():
    """Auto-disable must leave the mesh instance solvable, not pinned."""
    grid = parse_arch("mesh-3x3").grid()
    res = map_dfg(_chain(), grid, MapperConfig(backend="cdcl",
                                               symmetry_break=True,
                                               ii_max=6))
    assert res.status == "mapped"
    assert not validate_mapping(res.mapping)


# ---------------------------------------------------------------------------
# UNSAT witnesses: memory ports are real clauses, not docstrings
# ---------------------------------------------------------------------------


def _two_loads():
    """Two independent loads — zero mobility, so at any II both sit in
    the same KMS row: a 1-port fabric must reject, a 2-port one accept."""
    return DFG([Node(1, op="LWI"), Node(2, op="LWI")], [], name="two-loads")


def test_two_mem_ops_exceed_one_port_unsat_witness():
    dfg = _two_loads()
    one_port = parse_arch("torus-2x2:ports=1/global").grid()
    enc = _encode(dfg, one_port, ii=1)
    assert enc.stats.num_port_groups == 1
    status, _, _ = solve_cdcl(enc)
    assert status == "unsat"
    # the same cell with two ports maps at the same II
    two_ports = parse_arch("torus-2x2:ports=2/global").grid()
    status, model, _ = solve_cdcl(_encode(dfg, two_ports, ii=1))
    assert status == "sat"
    # and the mapper-level search agrees end to end
    res = map_dfg(dfg, one_port, MapperConfig(backend="cdcl", ii_max=4))
    assert res.status == "unsat-capped"
    res2 = map_dfg(dfg, two_ports, MapperConfig(backend="cdcl", ii_max=4))
    assert res2.status == "mapped" and res2.ii == 1
    assert not validate_mapping(res2.mapping)


def test_per_column_port_allows_different_columns():
    """1 port *per column* only serializes same-column loads."""
    dfg = _two_loads()
    grid = parse_arch("torus-2x2:ports=1/col").grid()
    res = map_dfg(dfg, grid, MapperConfig(backend="cdcl", ii_max=4))
    assert res.status == "mapped" and res.ii == 1
    cols = {res.mapping.placements[n].pe % 2 for n in (1, 2)}
    assert cols == {0, 1}  # forced into distinct columns
    assert not validate_mapping(res.mapping)


def test_capability_unplaceable_is_trivially_unsat():
    dfg = _two_loads()
    grid = parse_arch("torus-2x2:mem=none").grid()
    enc = _encode(dfg, grid, ii=1)
    assert enc.stats.unplaceable_nodes == [1, 2]
    assert enc.is_trivially_unsat
    status, _, _ = solve_cdcl(enc)
    assert status == "unsat"


def test_mul_capability_pins_placement():
    dfg = DFG([Node(1, op="SADD"), Node(2, op="SMUL")], [Edge(1, 2)],
              name="mul-pin")
    grid = parse_arch("torus-3x3:mul=pe4").grid()
    res = map_dfg(dfg, grid, MapperConfig(backend="cdcl", ii_max=4))
    assert res.status == "mapped"
    assert res.mapping.placements[2].pe == 4
    assert not validate_mapping(res.mapping)


# ---------------------------------------------------------------------------
# validate_mapping is an independent referee
# ---------------------------------------------------------------------------


def test_validator_rejects_mem_op_off_the_border():
    grid = PRESETS["bordermem-4x4"].grid()
    dfg = DFG([Node(1, op="LWI")], [], name="one-load")
    bad = Mapping(dfg=dfg, grid=grid, ii=1, num_folds=1,
                  placements={1: Placement(1, pe=5, slot=Slot(0, 0))})
    errs = validate_mapping(bad, check_registers=False)
    assert any("load-store" in e for e in errs)


def test_validator_rejects_port_conflict():
    grid = PRESETS["bordermem-4x4"].grid()  # 1 port per column
    dfg = _two_loads()
    bad = Mapping(dfg=dfg, grid=grid, ii=1, num_folds=1,
                  placements={1: Placement(1, pe=0, slot=Slot(0, 0)),
                              2: Placement(2, pe=4, slot=Slot(0, 0))})
    errs = validate_mapping(bad, check_registers=False)
    assert any("port group col0" in e for e in errs)


def test_validator_rejects_mul_without_multiplier():
    grid = parse_arch("torus-3x3:mul=pe0").grid()
    dfg = DFG([Node(1, op="SMUL")], [], name="one-mul")
    bad = Mapping(dfg=dfg, grid=grid, ii=1, num_folds=1,
                  placements={1: Placement(1, pe=8, slot=Slot(0, 0))})
    errs = validate_mapping(bad, check_registers=False)
    assert any("multiplier" in e for e in errs)


# ---------------------------------------------------------------------------
# cache keys: hetero specs hash in, homogeneous keys stay byte-identical
# ---------------------------------------------------------------------------


def test_homogeneous_spec_key_equals_legacy_grid_key():
    dfg = _chain()
    assert parse_arch("4x4").grid().arch_fingerprint() is None
    assert (mapping_cache_key(dfg, parse_arch("4x4").grid())
            == mapping_cache_key(dfg, make_grid(4, 4)))
    assert (mapping_cache_key(dfg, parse_arch("mesh-4x4").grid())
            == mapping_cache_key(dfg, make_grid(4, 4, torus=False)))


def test_hetero_specs_get_distinct_keys():
    dfg = _chain()
    keys = {mapping_cache_key(dfg, parse_arch(a).grid())
            for a in ("4x4", "openedge-4x4", "bordermem-4x4",
                      "torus-4x4:mem=border", "diag-4x4")}
    assert len(keys) == 5


def test_fingerprint_ignores_names():
    named = PRESETS["bordermem-4x4"].grid()
    anon = parse_arch("torus-4x4:mem=border,ports=1/col").grid()
    assert named.arch_fingerprint() == anon.arch_fingerprint()


# ---------------------------------------------------------------------------
# energy/area model
# ---------------------------------------------------------------------------


def test_capability_aware_area_orders_fabrics():
    homog = make_grid(4, 4)
    border = PRESETS["bordermem-4x4"].grid()
    alu_only = parse_arch("torus-4x4:mem=none,mul=none").grid()
    assert arch_area(alu_only) < arch_area(border) < arch_area(homog)
    assert arch_area(homog) == pytest.approx(16 * FULL_PE_AREA)
    caps = border.caps
    assert pe_area(border, 5) < pe_area(border, 0)  # interior lacks the LSU
    assert 5 not in caps.mem_pes and 0 in caps.mem_pes


def test_arch_caps_default_is_fully_capable():
    g = make_grid(2, 2)
    assert g.caps is None
    assert g.placeable_pes("LWI") == [0, 1, 2, 3]
    caps = ArchCaps()
    assert caps.to_dict()["mem_pes"] is None


# ---------------------------------------------------------------------------
# toolchain + DSE threading
# ---------------------------------------------------------------------------


def test_toolchain_compiles_hetero_spec_with_arch_label():
    tc = Toolchain("bordermem-4x4", CDCL)
    cr = tc.compile("dotprod")
    assert cr.ok
    assert cr.arch == "bordermem-4x4"
    assert cr.summary()["arch"] == "bordermem-4x4"
    assert not validate_mapping(cr.mapping)
    # the homogeneous digest stays arch-free (committed-baseline contract)
    plain = Toolchain("4x4", CDCL).compile("dotprod")
    assert plain.arch is None and "arch" not in plain.summary()


def test_compile_many_distinguishes_same_size_archs(tmp_path):
    tc = Toolchain("4x4", CDCL, cache=str(tmp_path / "cache"))
    out = tc.compile_many(["dotprod"], grids=["4x4", "bordermem-4x4"],
                          jobs=1)
    assert [cr.arch for cr in out] == [None, "bordermem-4x4"]
    assert all(cr.ok for cr in out)
    # distinct cache entries: a second run hits both
    again = tc.compile_many(["dotprod"], grids=["4x4", "bordermem-4x4"],
                            jobs=1)
    assert [cr.cache_hit for cr in again] == [True, True]


def test_arch_space_cross_product():
    from repro.dse.space import arch_space, build_arch_space
    specs = arch_space(("torus", "mesh"), ("", "mem=col0"), [(3, 3)])
    assert specs == ["torus-3x3", "torus-3x3:mem=col0",
                     "mesh-3x3", "mesh-3x3:mem=col0"]
    pts = build_arch_space(["dotprod"], specs)
    assert len(pts) == 4 and pts[0].arch == "torus-3x3"
    with pytest.raises(ValueError):
        build_arch_space(["nope"], specs)
    with pytest.raises(ArchSpecError):
        build_arch_space(["dotprod"], ["ring-9x9"])


# ---------------------------------------------------------------------------
# acceptance: the border-mem hetero 4x4 maps the registry
# ---------------------------------------------------------------------------


ACCEPT_KERNELS = ("dotprod", "saxpy", "prefix_sum", "popcount", "argmax",
                  "ema_fxp", "bitcount", "reversebits")


def test_bordermem_4x4_maps_registry_kernels():
    """>= 8 registry kernels map on the border-mem hetero spec with every
    mem op on a mem-capable PE and zero per-cycle port conflicts —
    asserted by validate_mapping *and* re-derived here by hand."""
    from repro.cgra.arch import MEM_OPS
    grid = PRESETS["bordermem-4x4"].grid()
    tc = Toolchain(grid, CDCL)
    mapped = 0
    for name in ACCEPT_KERNELS:
        cr = tc.compile(name)
        assert cr.ok, f"{name}: {cr.status} at {cr.stage} ({cr.error})"
        mapping = cr.mapping
        assert validate_mapping(mapping) == []
        for n, pl in mapping.placements.items():
            if mapping.dfg.nodes[n].op in MEM_OPS:
                assert pl.pe in grid.caps.mem_pes
        for _label, pes, limit in grid.caps.port_groups:
            for c in range(mapping.ii):
                users = [n for n, pl in mapping.placements.items()
                         if pl.pe in pes and pl.slot.c == c
                         and mapping.dfg.nodes[n].op in MEM_OPS]
                assert len(users) <= limit
        mapped += 1
    assert mapped >= 8
