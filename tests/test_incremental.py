"""Incremental solving engine: CDCL unit tests + mapper cross-checks.

The incremental path must be a pure optimization: same status, same final
II, and a valid decoded mapping, while provably reusing the per-II
encoding and solver session (counters in MapResult).
"""
import importlib.util

import pytest

from repro.cgra import make_grid
from repro.cgra.programs import BENCHMARKS
from repro.cgra.simulator import map_for_execution
from repro.core import MapperConfig, validate_mapping
from repro.sat import CDCLSolver, CNF
from repro.sat.cdcl import luby

HAS_Z3 = importlib.util.find_spec("z3") is not None

BACKENDS = ["cdcl"] + (["z3"] if HAS_Z3 else [])

# small kernels so the cross-check stays fast on the pure-Python backend;
# gsm@2x2 is the CEGAR-active case (assembler rejects its first mapping)
KERNELS = [("bitcount", 2), ("reversebits", 2), ("gsm", 2),
           ("stringsearch", 2), ("sqrt", 3)]


# ---------------------------------------------------------------------------
# CDCL solver unit tests
# ---------------------------------------------------------------------------


def test_luby_sequence():
    assert [luby(i) for i in range(15)] == \
        [1, 1, 2, 1, 1, 2, 4, 1, 1, 2, 1, 1, 2, 4, 8]


def _pigeonhole(holes: int) -> CNF:
    """holes+1 pigeons into `holes` holes — UNSAT, forces real learning."""
    cnf = CNF()
    n = holes + 1
    var = {(p, h): cnf.new_var() for p in range(n) for h in range(holes)}
    for p in range(n):
        cnf.add_clause([var[(p, h)] for h in range(holes)])
    for h in range(holes):
        for p1 in range(n):
            for p2 in range(p1 + 1, n):
                cnf.add_clause((-var[(p1, h)], -var[(p2, h)]))
    return cnf


def test_learned_clauses_survive_add_clauses():
    cnf = _pigeonhole(4)
    del cnf.clauses[0]  # drop pigeon 0's at-least-one clause -> SAT
    s = CDCLSolver(cnf)
    assert s.solve(timeout_s=30) == "sat"
    learned_before = s.stats.learned
    assert learned_before > 0
    db_before = len(s.clauses)
    model = s.model()
    blocking = tuple(-v if model[v] else v for v in range(1, s.nvars + 1))
    assert s.add_clauses([blocking])
    # learned clauses and clause DB intact, new clause appended
    assert s.stats.learned == learned_before
    assert len(s.clauses) == db_before + 1
    res = s.solve(timeout_s=30)
    assert res in ("sat", "unsat")
    if res == "sat":
        assert s.model() != model


def test_add_clauses_can_flip_to_unsat_and_stays_unsat():
    cnf = CNF()
    cnf.ensure_var(2)
    cnf.extend([(1, 2)])
    s = CDCLSolver(cnf)
    assert s.solve() == "sat"
    assert not s.add_clauses([(-1,), (-2,)])
    assert s.solve() == "unsat"
    assert s.solve() == "unsat"  # terminal: stays unsat on re-query


def test_incremental_blocking_matches_fresh_solver():
    """Adding blocking clauses one at a time enumerates exactly the models
    a fresh solver sees on the full CNF."""
    base = [(1, 2, 3), (-1, -2), (-2, -3)]
    s = CDCLSolver()
    s.ensure_var(3)
    s.add_clauses(base)
    seen = []
    while s.solve() == "sat":
        m = s.model()
        seen.append(tuple(sorted(v for v in (1, 2, 3) if m[v])))
        s.add_clauses([tuple(-v if m[v] else v for v in (1, 2, 3))])
        assert len(seen) < 10
    # brute-force reference model count
    ref = []
    for a in range(8):
        assign = {v: bool((a >> (v - 1)) & 1) for v in (1, 2, 3)}
        if all(any(assign[abs(l)] == (l > 0) for l in c) for c in base):
            ref.append(tuple(sorted(v for v in (1, 2, 3) if assign[v])))
    assert sorted(seen) == sorted(ref)


def test_assumptions_are_undone():
    cnf = CNF()
    cnf.ensure_var(4)
    cnf.extend([(1, 2), (-1, 3), (-2, 4)])
    s = CDCLSolver(cnf)
    assert s.solve(assumptions=(-1,)) == "sat"
    m = s.model()
    assert not m[1] and m[2] and m[4]
    # assumption gone: the opposite polarity is reachable again
    assert s.solve(assumptions=(1,)) == "sat"
    assert s.model()[1]
    assert s.solve() == "sat"
    # nothing about var 1 is permanently forced
    assert s.assign[1] == 0


def test_assumptions_unsat_does_not_poison_solver():
    cnf = CNF()
    cnf.ensure_var(3)
    cnf.extend([(1, 2), (-1, 3), (-2, 3)])  # implies 3
    s = CDCLSolver(cnf)
    assert s.solve(assumptions=(-3,)) == "unsat"
    assert s.solve() == "sat"               # still sat without assumptions
    assert s.model()[3]
    assert s.solve(assumptions=(-3,)) == "unsat"


def test_unsat_instance_with_learning():
    s = CDCLSolver(_pigeonhole(4))
    assert s.solve(timeout_s=30) == "unsat"
    assert s.stats.conflicts > 0


def test_restarts_terminate():
    """Regression: the Luby helper used to loop forever at index 1, hanging
    any solve that reached its first restart."""
    s = CDCLSolver(_pigeonhole(5))
    res = s.solve(timeout_s=60)
    assert res == "unsat"
    assert s.stats.restarts >= 1


# ---------------------------------------------------------------------------
# mapper cross-checks: incremental == from-scratch
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("backend", BACKENDS)
@pytest.mark.parametrize("name,size", KERNELS)
def test_incremental_matches_cold(name, size, backend):
    prog = BENCHMARKS[name]()
    grid = make_grid(size, size)
    results = {}
    for inc in (False, True):
        cfg = MapperConfig(backend=backend, incremental=inc,
                           per_ii_timeout_s=30, total_timeout_s=60,
                           ii_max=20)
        results[inc] = map_for_execution(prog, grid, cfg)
    cold, incr = results[False], results[True]
    assert cold.status == incr.status
    assert cold.ii == incr.ii
    if incr.mapping is not None:
        assert validate_mapping(incr.mapping) == []
        # every solve consumed exactly one encoding in cold mode...
        sat_unknown = [a for a in cold.attempts]
        assert cold.encodings_built == len(sat_unknown)
        assert cold.incremental_solves == 0
        # ...while the incremental engine builds one encoding per II and
        # re-solves CEGAR rounds on the warm session
        distinct_iis = len({a.ii for a in incr.attempts})
        assert incr.encodings_built == distinct_iis
        assert incr.incremental_solves == len(incr.attempts) - distinct_iis


def test_cegar_rounds_reuse_encoding():
    """gsm on 2x2 is CEGAR-active: the assembler rejects the first mapping
    (prologue clobber), so the same II is re-solved.  The re-solve must hit
    the cached encoding, not a rebuild."""
    prog = BENCHMARKS["gsm"]()
    grid = make_grid(2, 2)
    cfg = MapperConfig(backend="cdcl", per_ii_timeout_s=30,
                       total_timeout_s=60)
    res = map_for_execution(prog, grid, cfg)
    assert res.status == "mapped"
    assert res.cegar_rounds >= 1
    assert len(res.attempts) >= 2
    # one encoding for the single II attempted, despite multiple solves
    assert res.encodings_built == 1
    assert res.incremental_solves == len(res.attempts) - 1
    assert res.attempts[0].incremental is False
    assert all(a.incremental for a in res.attempts[1:])


def test_construction_budget_enforced():
    """total_timeout_s now covers Python-side encoding construction: an
    absurdly small budget must yield status 'timeout', not a long stall."""
    import time
    from repro.cgra.programs import synthetic_dfg
    from repro.core import map_dfg
    dfg = synthetic_dfg("hotspot")  # 67 nodes — encoding is the cost
    grid = make_grid(4, 4)
    t0 = time.monotonic()
    res = map_dfg(dfg, grid, MapperConfig(backend="cdcl",
                                          total_timeout_s=0.05))
    assert res.status == "timeout"
    assert time.monotonic() - t0 < 10.0
